"""Beyond-paper: elastic virtual clusters — churn rate x fleet size sweep.

Runs all five algorithms on rented fleets under the named churn scenarios
(``repro.sim.workloads.churn_scenarios``): VPS failures with replacement,
spot preemption, and lease-expiry cycling, each with a backlog-driven
autoscaler where the scenario calls for one. Reports the tenant-facing
economics the static simulator cannot see: VPS-hours, dollar cost,
work-lost MB (finished map output destroyed with departed disks) and the
forced re-execution count, next to the WTT the paper measures.

Claim checks:
  * the ``stable`` scenario (fixed fleet, zero churn) is bit-identical to
    the static simulator for every algorithm;
  * churn runs are deterministic per seed;
  * every job completes under churn, and no task is ever assigned to a
    departed host;
  * churn costs re-executed work (re-exec count > 0 somewhere in the sweep).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import table
from repro.core.joss import make_algorithm
from repro.elastic import (BacklogThresholdScaler, ChurnConfig,
                           CostCappedSpotScaler, ElasticEngine, FixedFleet)
from repro.sim.cluster_sim import Simulator
from repro.sim.workloads import (churn_scenarios, make_cluster,
                                 profiling_prelude, small_workload)

ALGOS = ("joss-t", "joss-j", "fifo", "fair", "capacity")


def _autoscaler_for(scenario: str, n_hosts: int):
    """Scenario-appropriate policy: fixed fleet for stable/flaky (the
    provider replaces failures), renewal-driven backlog scaling for lease
    cycling, and a cost-capped spot mix for the spot scenario."""
    if scenario == "lease":
        return BacklogThresholdScaler(min_hosts=max(2, n_hosts // 2),
                                      max_hosts=2 * n_hosts)
    if scenario == "spot":
        return CostCappedSpotScaler(budget=0.25 * n_hosts,
                                    min_hosts=max(2, n_hosts // 2),
                                    max_hosts=2 * n_hosts)
    return FixedFleet()


def _run(name: str, hosts_per_pod, scenario: str, cfg_kw: dict,
         n_jobs: int, seed: int = 11):
    cluster = make_cluster(hosts_per_pod)
    jobs = small_workload(cluster, seed=seed, n_jobs=n_jobs)
    algo = make_algorithm(name, cluster)
    if hasattr(algo, "registry"):
        for j in profiling_prelude(cluster):
            algo.registry.record(j, j.true_fp)
    elastic = None
    if scenario is not None:
        churn = ChurnConfig(seed=seed + 1, **cfg_kw) if cfg_kw else None
        elastic = ElasticEngine(
            cluster, churn=churn,
            autoscaler=_autoscaler_for(scenario, sum(hosts_per_pod)))
    res = Simulator(cluster, algo, jobs, seed=seed, elastic=elastic).run()
    assert len(res.job_finish) == len(jobs), \
        f"{name}/{scenario}: {len(res.job_finish)}/{len(jobs)} jobs finished"
    if res.elastic is not None:
        removed = {hid: t for (t, hid, _r) in res.elastic.loss_log}
        for log in res.task_logs:
            # strict <: a task started at the removal instant would mean a
            # stale slot offer (legit completions always start earlier, and
            # same-instant starts on the host are killed before logging)
            assert (log.host not in removed
                    or log.start < removed[log.host]), \
                f"{name}/{scenario}: task assigned to departed {log.host}"
    return res


def _static_sig(res):
    return (res.wtt, res.int_bytes, res.pod_bytes,
            tuple(sorted(res.job_finish.values())))


def run(quick: bool = False) -> str:
    fleets = [(8, 8)] if quick else [(8, 8), (32, 32)]
    n_jobs = 20 if quick else 40
    scenarios = churn_scenarios()

    rows: List[List] = []
    reexec_total = 0
    for hosts_per_pod in fleets:
        for scen, cfg_kw in scenarios.items():
            for name in ALGOS:
                res = _run(name, hosts_per_pod, scen, cfg_kw, n_jobs)
                reexec_total += res.n_reexec
                rows.append([
                    f"{len(hosts_per_pod)}x{hosts_per_pod[0]}", scen, name,
                    res.wtt, res.vps_hours, res.cost_dollars,
                    res.work_lost_mb, res.n_reexec,
                    res.n_host_losses, res.n_host_adds])
    out = table(
        "Elastic clusters — churn scenario x fleet x algorithm "
        "(VPS-hours / $ at the engine's default price sheet)",
        ["fleet", "scenario", "algo", "wtt s", "VPS-h", "$", "lost MB",
         "re-exec", "losses", "adds"], rows)

    # claim check: zero-churn elastic == static simulator, bit-identical
    for name in ALGOS:
        static = _run(name, fleets[0], None, {}, n_jobs)
        stable = _run(name, fleets[0], "stable", {}, n_jobs)
        assert _static_sig(static) == _static_sig(stable), \
            f"stable-scenario run diverged from static simulator for {name}"
    out += ("\n\n[claim check: stable scenario bit-identical to the static "
            "simulator for all 5 algorithms]")

    # claim check: determinism per seed (repeat one churn run)
    a = _run("joss-t", fleets[0], "flaky", scenarios["flaky"], n_jobs)
    b = _run("joss-t", fleets[0], "flaky", scenarios["flaky"], n_jobs)
    assert (_static_sig(a), a.n_reexec, a.vps_hours, a.cost_dollars) == \
           (_static_sig(b), b.n_reexec, b.vps_hours, b.cost_dollars), \
        "churn run is not deterministic per seed"
    out += "\n[claim check: churn runs deterministic per seed]"

    assert reexec_total > 0, "churn sweep produced no re-executions"
    return out


if __name__ == "__main__":
    print(run())
