"""PR 8 tentpole: the vectorized Monte-Carlo sweep engine and its
statistical claims.

Everything statistical in the repo now flows through
``repro.sweep``: the (algorithm x scenario x seed) run matrices, the
content-addressed result store, and the bootstrap-CI aggregation that
turns per-cell metrics into the claim rows committed in
``BENCH_fabric.json`` / ``BENCH_elastic.json``. This bench measures the
orchestrator itself and asserts its contracts:

  * **throughput** — re-running the full contention matrix against a
    warm content-addressed store must be >= ``MIN_SWEEP_SPEEDUP`` (20x)
    faster per cell than the serial single-process baseline
    (``run_serial``); on unchanged code a sweep re-run is effectively
    free, which is what makes 32-seed statistical gates affordable in
    CI;
  * **determinism** — the same sub-matrix through an inline engine, a
    shuffled submission order, and a spawn pool produces bit-identical
    per-cell metric dicts and a byte-identical aggregate JSON (workers
    re-derive every RNG stream from the cell key and *poison* their
    inherited globals, so pool state cannot leak into results);
  * **cache transparency** — cells served from the store equal the
    freshly-executed ones bit-for-bit, and a fully warm re-run executes
    zero simulations;
  * **vmap equivalence** — the batched ``jax.vmap`` progressive-fill
    kernel (``repro.sweep.vmap_fill``) is held against real fill
    problems captured from a contended run: the scalar reference is
    **bit-identical** to what the live allocator recorded, the batched
    kernel is bit-close (``RTOL``) with identical completion orderings,
    plus a problems/s microbench of batched vs serial evaluation.

Statistical claims (the paper's Fig. 12 story with error bars, n_seeds
>= 32 on full runs):

  * the per-seed paired WTT gap (mean baseline - mean JoSS) has a
    bootstrap CI entirely above zero at every oversubscribed level —
    JoSS's win is statistically significant, not a lucky seed;
  * the mean gap widens with WAN oversubscription;
  * at every contention level, the worst JoSS INT CI sits entirely
    below the best baseline INT CI (disjoint intervals).

Full (non-quick, non-fast) runs write ``BENCH_sweep.json`` (orchestrator
gate + determinism + vmap rows) and refresh the ``claims`` blocks of
``BENCH_fabric.json`` and ``BENCH_elastic.json`` in place — claims can
be updated without re-running the expensive fabric scale sweeps.
``scripts/check_bench_regression.py`` gates all three: the committed
speedup must hold the 20x envelope (re-measured fresh), every committed
claim row must carry n >= 32 with a CI, and a fresh reduced-seed sweep
must not produce a CI disjoint from the stored one in the bad
direction.
"""
from __future__ import annotations

import hashlib
import json
import os
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from benchmarks.common import table
from repro.sweep import (ResultStore, SweepEngine, aggregate,
                         aggregate_cells, aggregate_json,
                         code_fingerprint, matrix, run_serial)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_sweep.json")
FABRIC_JSON_PATH = os.path.join(_ROOT, "BENCH_fabric.json")
ELASTIC_JSON_PATH = os.path.join(_ROOT, "BENCH_elastic.json")

ALGOS = ("joss-t", "joss-j", "fifo", "fair", "capacity")
JOSS = ("joss-t", "joss-j")
BASELINES = ("fifo", "fair", "capacity")

#: the contention matrix (the bench_fabric sweep with seeds): WAN
#: oversubscription levels from repro.sweep.cells.WAN_OVERSUB
SCENARIOS = ("uncontended", "oversub8", "oversub24")
#: the elastic churn matrix (the bench_elastic sweep with seeds)
ELASTIC_SCENARIOS = ("flaky", "spot")

#: metrics carried as committed claim rows (means + bootstrap CIs)
FABRIC_CLAIM_METRICS = ("wtt", "int_mb")
ELASTIC_CLAIM_METRICS = ("wtt", "work_lost_mb", "cost_dollars",
                         "n_reexec")

#: the orchestrator acceptance envelope: warm-store cells/s over the
#: serial single-process baseline at the full contention matrix
MIN_SWEEP_SPEEDUP = 20.0

#: the PR 9 lockstep acceptance envelope: scalar inline fill-path
#: seconds over lockstep batched fill-path seconds at the committed
#: gate point (fill-path throughput, not end-to-end wall — stepping
#: the simulators costs the same either way and dilutes the ratio)
MIN_LOCKSTEP_FILL_SPEEDUP = 3.0

#: the lockstep gate point: 8 pods x 8 hosts, 24 jobs — 17 fabric
#: links and fills spanning up to ~47 traffic classes, large enough
#: that the batched kernel beats the scalar allocator per problem
LOCKSTEP_HOSTS_PER_POD = (8,) * 8
LOCKSTEP_N_JOBS = 24

#: replicas per (algorithm, scenario) point on full sweeps — the floor
#: every committed claim row must carry
FULL_SEEDS = 32
FAST_SEEDS = 8


def sweep_seeds(reduced: bool) -> int:
    """Replica count: ``SWEEP_SEEDS`` env override, else 32 full /
    8 reduced (the --fast PR lane and --quick CI stages)."""
    env = os.environ.get("SWEEP_SEEDS")
    if env:
        return max(2, int(env))
    return FAST_SEEDS if reduced else FULL_SEEDS


def contention_matrix(n_seeds: int) -> list:
    return matrix("fabric_contention", ALGOS, SCENARIOS, n_seeds,
                  hosts_per_pod=(8, 8), n_jobs=12)


def elastic_matrix(n_seeds: int) -> list:
    return matrix("elastic_churn", ALGOS, ELASTIC_SCENARIOS, n_seeds,
                  fleet=(8, 8), n_jobs=40)


def lockstep_matrix(n_seeds: int) -> list:
    """The lockstep gate matrix: the contention family at the larger
    8x8-pod / 24-job operating point (480 cells at 32 seeds)."""
    return matrix("fabric_contention", ALGOS, SCENARIOS, n_seeds,
                  hosts_per_pod=LOCKSTEP_HOSTS_PER_POD,
                  n_jobs=LOCKSTEP_N_JOBS)


def _by_spec(results: Dict[str, dict]) -> Dict[tuple, dict]:
    """{(algo, scenario, seed): metrics} view of an engine result."""
    out = {}
    for key, metrics in results.items():
        d = json.loads(key)
        out[(d["algo"], d["scenario"], d["seed"])] = metrics
    return out


def fabric_claims(results: Dict[str, dict]) -> Tuple[List[dict],
                                                     List[dict]]:
    """The committed fabric claim rows: per-(scenario, algo) summary
    rows for ``FABRIC_CLAIM_METRICS``, plus one paired-gap row per
    scenario — ``gap_i = mean(baseline WTT) - mean(JoSS WTT)`` within
    replica ``i``, aggregated over replicas. Pairing by replica index
    cancels none of the variance (each cell derives its own seed) but
    keeps the row count independent of the algorithm split."""
    rows = aggregate_cells(results, metrics=FABRIC_CLAIM_METRICS)
    cells = _by_spec(results)
    seeds = sorted({s for (_, _, s) in cells})
    gaps: List[dict] = []
    for scen in SCENARIOS:
        vals = []
        for i in seeds:
            mean_joss = sum(cells[(a, scen, i)]["wtt"]
                            for a in JOSS) / len(JOSS)
            mean_base = sum(cells[(a, scen, i)]["wtt"]
                            for a in BASELINES) / len(BASELINES)
            vals.append(mean_base - mean_joss)
        row = {"scenario": scen, "metric": "wtt_gap"}
        row.update(aggregate(vals, key=f"{scen}:wtt_gap"))
        gaps.append(row)
    return rows, gaps


def elastic_claims(results: Dict[str, dict]) -> List[dict]:
    """The committed elastic claim rows: per-(scenario, algo) summary
    rows for ``ELASTIC_CLAIM_METRICS``."""
    return aggregate_cells(results, metrics=ELASTIC_CLAIM_METRICS)


def claim_row(rows: Sequence[dict], scenario: str, algo: Optional[str],
              metric: str) -> dict:
    for r in rows:
        if (r.get("scenario") == scenario and r.get("metric") == metric
                and r.get("algo", None) == algo):
            return r
    raise KeyError((scenario, algo, metric))


def _merge_key(path: str, key: str, value: dict) -> None:
    """Read-modify-write one top-level block of a committed BENCH
    file, preserving every block another bench owns (e.g. the
    migration row bench_migration owns in BENCH_elastic.json, or the
    lockstep block in BENCH_sweep.json)."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except OSError:
        payload = {}
    payload[key] = value
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def _merge_claims(path: str, claims: dict) -> None:
    _merge_key(path, "claims", claims)


def refresh_fabric_claims(n_seeds: int = FULL_SEEDS) -> Tuple[List[dict],
                                                              List[dict]]:
    """Recompute and re-commit BENCH_fabric.json's claims block through
    the orchestrator (free on unchanged code thanks to the store) —
    lets a full --only fabric sweep refresh its claim rows without
    re-running this bench, and vice versa."""
    engine = SweepEngine(workers=1, store=ResultStore())
    results, _ = engine.run(contention_matrix(n_seeds))
    rows, gaps = fabric_claims(results)
    _merge_claims(FABRIC_JSON_PATH,
                  {"n_seeds": n_seeds, "rows": rows, "gaps": gaps})
    return rows, gaps


def refresh_elastic_claims(n_seeds: int = FULL_SEEDS) -> List[dict]:
    """BENCH_elastic.json counterpart of :func:`refresh_fabric_claims`
    (the migration row and gated points are preserved)."""
    engine = SweepEngine(workers=1, store=ResultStore())
    results, _ = engine.run(elastic_matrix(n_seeds))
    rows = elastic_claims(results)
    _merge_claims(ELASTIC_JSON_PATH, {"n_seeds": n_seeds, "rows": rows})
    return rows


def run(quick: bool = False, fast: bool = False) -> str:
    n_seeds = sweep_seeds(quick or fast)
    write = not (quick or fast)
    fp = code_fingerprint()
    store = ResultStore()
    engine = SweepEngine(workers=1, store=store)
    out = (f"\n## Sweep engine — run-matrix orchestrator "
           f"(n_seeds={n_seeds}, store fingerprint {fp[:16]})")

    # ------------------------------------------------ execute matrices --
    specs = contention_matrix(n_seeds)
    results, cold = engine.run(specs)
    e_specs = elastic_matrix(n_seeds)
    e_results, e_cold = engine.run(e_specs)
    out += (f"\n\ncontention matrix: {cold.n_cells} cells "
            f"({cold.n_cached} cached, {cold.n_executed} executed, "
            f"{cold.wall_s:.1f}s); elastic matrix: {e_cold.n_cells} "
            f"cells ({e_cold.n_cached} cached, {e_cold.n_executed} "
            f"executed, {e_cold.wall_s:.1f}s)")

    # --------------------------------------- throughput: warm vs serial --
    results_warm, warm = engine.run(specs)
    assert warm.n_executed == 0, \
        "warm sweep re-executed cells the store should have served"
    assert results_warm == results, \
        "warm (cached) sweep diverged from the executed results"
    sample = [s for s in specs if s.seed < max(1, min(2, n_seeds))]
    t0 = time.perf_counter()
    serial_results = run_serial(sample)
    serial_s = time.perf_counter() - t0
    serial_cps = len(sample) / serial_s
    speedup = warm.cells_per_s / serial_cps
    assert speedup >= MIN_SWEEP_SPEEDUP, \
        f"warm sweep only {speedup:.1f}x the serial baseline " \
        f"(need >= {MIN_SWEEP_SPEEDUP:.0f}x)"
    assert all(results[k] == v for k, v in serial_results.items()), \
        "serial baseline diverged from the orchestrated results"
    out += "\n" + table(
        "Sweep throughput — warm content-addressed store vs serial "
        f"single-process baseline ({warm.n_cells}-cell contention "
        "matrix; the envelope the CI gate re-checks)",
        ["path", "cells", "wall s", "cells/s"],
        [["serial (sample)", len(sample), f"{serial_s:.2f}",
          f"{serial_cps:.1f}"],
         ["warm store", warm.n_cells, f"{warm.wall_s:.3f}",
          f"{warm.cells_per_s:.0f}"],
         ["speedup", "-", "-", f"{speedup:.0f}x"]])
    out += (f"\n[claim check: warm sweep >= {MIN_SWEEP_SPEEDUP:.0f}x "
            f"serial ({speedup:.0f}x), re-run executed 0 cells, cached "
            "== executed bit-for-bit]")

    # ------------------------------------------------ determinism claims --
    det = [s for s in specs if s.seed == 0]
    r_inline, _ = SweepEngine(workers=1, store=None).run(det)
    shuffled = random.Random(0xC0FFEE).sample(det, len(det))
    r_shuf, _ = SweepEngine(workers=1, store=None).run(shuffled)
    n_pool = 2 if (quick or fast) else 4
    r_pool, _ = SweepEngine(workers=n_pool, store=None).run(det)
    assert r_inline == r_shuf, \
        "shuffled submission order changed per-cell results"
    assert r_inline == r_pool, \
        f"pool-of-{n_pool} diverged from the inline engine"
    agg_a = aggregate_json(r_inline, metrics=FABRIC_CLAIM_METRICS)
    agg_b = aggregate_json(r_shuf, metrics=FABRIC_CLAIM_METRICS)
    agg_c = aggregate_json(r_pool, metrics=FABRIC_CLAIM_METRICS)
    assert agg_a == agg_b == agg_c, \
        "aggregate JSON is not byte-identical across schedules"
    assert all(results[k] == v for k, v in r_inline.items()), \
        "store-served cells diverged from a fresh no-store run"
    agg_sha = hashlib.sha256(agg_a.encode()).hexdigest()
    out += (f"\n[claim check: inline == shuffled-order == "
            f"pool-of-{n_pool} bit-identical on {len(det)} cells; "
            f"aggregate JSON byte-identical (sha {agg_sha[:12]}...)]")

    # ------------------------------------------------------ vmap kernel --
    from repro.sweep import vmap_fill as vf
    snaps = vf.contention_snapshots(
        "joss-t", "oversub8", limit=120 if (quick or fast) else 240)
    rec_rates = [np.array([c["rate"] for c in s["classes"]])
                 for s in snaps]
    for s, rec in zip(snaps, rec_rates):
        ref = vf.fill_reference(s)
        assert np.array_equal(np.asarray(ref["rates"]), rec), \
            "scalar fill reference diverged from the live allocator"
    out += (f"\n[claim check: scalar fill reference bit-identical to "
            f"the live allocator on {len(snaps)} captured fill "
            "problems]")
    vmap_row: dict = {"have_jax": vf.HAVE_JAX, "n_snapshots": len(snaps)}
    if vf.HAVE_JAX:
        batch = vf.batched_fill(snaps)          # compiles
        refb = vf.batched_fill_reference(snaps)
        assert np.allclose(batch["rates"], refb["rates"], rtol=vf.RTOL,
                           atol=0.0), "batched fill rates out of RTOL"
        assert np.allclose(batch["dt_next"], refb["dt_next"],
                           rtol=vf.RTOL, equal_nan=True), \
            "batched completion fronts out of RTOL"
        for i in range(len(snaps)):
            assert vf.orderings_match(refb["etas"][i],
                                      batch["etas"][i]), \
                f"completion ordering changed on snapshot {i}"
        t0 = time.perf_counter()
        vf.batched_fill(snaps)                   # warm, compiled
        batched_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        vf.batched_fill_reference(snaps)
        ref_s = time.perf_counter() - t0
        vmap_row.update(
            batched_problems_per_s=len(snaps) / batched_s,
            ref_problems_per_s=len(snaps) / ref_s,
            ratio=ref_s / batched_s, rtol=vf.RTOL)
        out += "\n" + table(
            "Batched fill kernel — problems/s over the captured corpus "
            "(vmap over independent fill problems vs the scalar loop)",
            ["path", "problems", "wall s", "problems/s"],
            [["vmap (jit, warm)", len(snaps), f"{batched_s:.3f}",
              f"{len(snaps) / batched_s:.0f}"],
             ["scalar loop", len(snaps), f"{ref_s:.3f}",
              f"{len(snaps) / ref_s:.0f}"]])
        out += (f"\n[claim check: batched kernel bit-close (rtol "
                f"{vf.RTOL:g}) to the scalar allocator with identical "
                f"completion orderings on all {len(snaps)} problems]")
    else:  # pragma: no cover - environment without jax
        out += "\n(jax unavailable: batched-kernel claims skipped)"

    # ------------------------------------------- statistical claim rows --
    rows, gaps = fabric_claims(results)
    e_rows = elastic_claims(e_results)
    assert all(r["n"] == n_seeds for r in rows + gaps + e_rows), \
        "claim rows lost replicas"
    g_disp = []
    for g in gaps:
        if g["scenario"] != "uncontended":
            assert g["ci_lo"] > 0.0, \
                f"JoSS WTT gap not significant under {g['scenario']}: " \
                f"CI [{g['ci_lo']:.1f}, {g['ci_hi']:.1f}]"
        g_disp.append([g["scenario"], f"{g['mean']:.1f}",
                       f"[{g['ci_lo']:.1f}, {g['ci_hi']:.1f}]",
                       g["n"]])
    for (a, b) in zip(gaps, gaps[1:]):
        assert b["mean"] > a["mean"], \
            f"mean WTT gap did not widen {a['scenario']} -> " \
            f"{b['scenario']}"
    for scen in SCENARIOS:
        worst_joss = max(claim_row(rows, scen, a, "int_mb")["ci_hi"]
                         for a in JOSS)
        best_base = min(claim_row(rows, scen, a, "int_mb")["ci_lo"]
                        for a in BASELINES)
        assert worst_joss < best_base, \
            f"INT CIs overlap under {scen}: joss hi {worst_joss:.0f} " \
            f"vs baseline lo {best_base:.0f}"
    out += "\n" + table(
        f"Paired WTT gap (mean baseline - mean JoSS) over {n_seeds} "
        "seeds — the paper's contention story with error bars "
        "(bootstrap 95% CI)",
        ["wan", "gap s", "95% CI", "n"], g_disp)
    out += ("\n[claim check: gap CI > 0 at every oversubscribed level, "
            "mean gap widens with oversubscription, and every JoSS INT "
            "CI is disjoint below every baseline INT CI]")

    # -------------------------------------------------- committed files --
    if write:
        # read-modify-write: the lockstep block (owned by run_lockstep)
        # survives a full sweep refresh
        for key, value in (
                ("matrix", {"family": "fabric_contention",
                            "algos": list(ALGOS),
                            "scenarios": list(SCENARIOS),
                            "n_seeds": n_seeds, "n_cells": cold.n_cells}),
                ("gate", {"n_seeds": n_seeds, "n_cells": warm.n_cells,
                          "serial_cells_per_s": serial_cps,
                          "warm_cells_per_s": warm.cells_per_s,
                          "speedup": speedup,
                          "serial_sample": len(sample),
                          "fingerprint": fp[:16]}),
                ("determinism", {"n_cells": len(det),
                                 "workers_checked": [1, n_pool],
                                 "aggregate_sha256": agg_sha}),
                ("vmap", vmap_row)):
            _merge_key(JSON_PATH, key, value)
        _merge_claims(FABRIC_JSON_PATH,
                      {"n_seeds": n_seeds, "rows": rows, "gaps": gaps})
        _merge_claims(ELASTIC_JSON_PATH,
                      {"n_seeds": n_seeds, "rows": e_rows})
        out += (f"\n\n[wrote {os.path.basename(JSON_PATH)}; refreshed "
                "claims blocks in BENCH_fabric.json and "
                "BENCH_elastic.json]")
    else:
        report = os.path.join(_ROOT, "SWEEP_REPORT.json")
        with open(report, "w") as f:
            json.dump({"n_seeds": n_seeds, "fingerprint": fp[:16],
                       "fabric": rows, "gaps": gaps,
                       "elastic": e_rows}, f, indent=1, sort_keys=True)
            f.write("\n")
        out += f"\n\n[reduced-seed run: aggregate report -> {report}]"
    return out


def _scalar_baseline(specs) -> Tuple[Dict[str, dict], float, float, int]:
    """Serial scalar reference for the lockstep table: every cell runs
    through the same lockstep builder but with a *timed* inline
    backend, so the fill-path seconds are the honest cost of the
    scalar allocator doing exactly the solves the inline path does
    (no deferral, no coalescing). Returns (results, wall_s, fill_s,
    n_fills)."""
    from repro.sim.network import InlineFillBackend
    from repro.sweep.cells import LOCKSTEP_BUILDERS
    results: Dict[str, dict] = {}
    fill_s = 0.0
    n_fills = 0
    t0 = time.perf_counter()
    for spec in specs:
        sim, finish = LOCKSTEP_BUILDERS[spec.family](spec)
        sim.begin()
        backend = InlineFillBackend(timed=True)
        sim.fabric.fill_backend = backend
        end = sim.step()
        results[spec.key()] = finish(sim.finish(end))
        fill_s += backend.fill_s
        n_fills += backend.n_fills
    wall_s = time.perf_counter() - t0
    return ({k: results[k] for k in sorted(results)},
            wall_s, fill_s, n_fills)


def run_lockstep(quick: bool = False, fast: bool = False) -> str:
    """PR 9 tentpole bench: the lockstep batched executor vs the
    scalar inline allocator vs the process pool, at the committed
    gate point (``LOCKSTEP_HOSTS_PER_POD`` x ``LOCKSTEP_N_JOBS``).

    Asserted claims:

      * **bit-identity** — lockstep per-cell metric dicts equal the
        scalar runs exactly (completion orderings included: the
        metrics are completion-derived) and the aggregate claim JSON
        is byte-identical;
      * **degradation** — without jax (``use_jax=False``) the
        executor's scalar deferred path reproduces the same results
        bit-for-bit;
      * **fill throughput** — the batched fill path is >=
        ``MIN_LOCKSTEP_FILL_SPEEDUP`` (3x) faster than the scalar
        allocator's fill path on full runs (half that as a smoke
        floor on reduced --quick/--fast lanes, where per-run noise
        on 120 cells is material).

    Full runs merge a ``lockstep`` block into ``BENCH_sweep.json``
    (read-modify-write — the orchestrator blocks ``run`` owns are
    preserved), which ``scripts/check_bench_regression.py`` gates.
    """
    from repro.sweep import LockstepExecutor
    from repro.sweep.vmap_fill import HAVE_JAX
    n_seeds = sweep_seeds(quick or fast)
    write = not (quick or fast)
    specs = lockstep_matrix(n_seeds)
    out = (f"\n## Lockstep batched execution — live simulation through "
           f"the vmap fill kernel ({len(specs)} cells at "
           f"{len(LOCKSTEP_HOSTS_PER_POD)}x"
           f"{LOCKSTEP_HOSTS_PER_POD[0]} hosts, "
           f"{LOCKSTEP_N_JOBS} jobs, n_seeds={n_seeds})")

    # ------------------------------------------------- scalar baseline --
    scalar, s_wall, s_fill, s_fills = _scalar_baseline(specs)

    # ------------------------------------------------ lockstep executor --
    ex = LockstepExecutor()
    res = ex.run(specs)
    st = ex.stats
    assert set(res) == set(scalar), "lockstep lost or invented cells"
    assert all(res[k] == scalar[k] for k in scalar), \
        "lockstep per-cell metrics diverged from the scalar runs"
    agg_l = aggregate_json(res, metrics=FABRIC_CLAIM_METRICS)
    agg_s = aggregate_json(scalar, metrics=FABRIC_CLAIM_METRICS)
    assert agg_l == agg_s, \
        "lockstep aggregate claim JSON is not byte-identical"
    agg_sha = hashlib.sha256(agg_l.encode()).hexdigest()

    # --------------------------------------- degradation without jax --
    nojax_specs = [s for s in specs if s.seed == 0]
    nojax = LockstepExecutor(use_jax=False).run(nojax_specs)
    assert all(nojax[s.key()] == scalar[s.key()] for s in nojax_specs), \
        "scalar deferred path (no jax) diverged from the inline runs"

    # ------------------------------------------------- process pool row --
    n_pool = 2 if (quick or fast) else 4
    t0 = time.perf_counter()
    r_pool, _ = SweepEngine(workers=n_pool, store=None).run(specs)
    pool_wall = time.perf_counter() - t0
    assert r_pool == scalar, \
        f"pool-of-{n_pool} diverged from the scalar baseline"

    # -------------------------------------------------------- the table --
    fill_speedup = s_fill / st.fill_s if st.fill_s > 0 else float("inf")
    coalesce = st.problems / max(1, s_fills)
    out += "\n" + table(
        "Lockstep vs scalar vs process pool — same cells, bit-identical "
        "metrics; 'fill s' is wall time inside the allocator (the gated "
        "axis), 'wall s' is end-to-end",
        ["path", "cells", "fill s", "fill solves", "wall s"],
        [["scalar inline", len(specs), f"{s_fill:.2f}", s_fills,
          f"{s_wall:.2f}"],
         ["lockstep (batched)", st.n_cells, f"{st.fill_s:.2f}",
          st.problems, f"{st.wall_s:.2f}"],
         [f"process pool x{n_pool}", len(r_pool), "-", "-",
          f"{pool_wall:.2f}"],
         ["fill speedup", "-", f"{fill_speedup:.2f}x", "-",
          f"{s_wall / st.wall_s:.2f}x"]])
    out += (f"\n[lockstep: {st.epochs} epochs, {st.batches} kernel "
            f"batches, {st.inline_small} small problems inlined, "
            f"deferred coalescing {coalesce:.2f}x "
            f"({st.problems} problems vs {s_fills} inline solves), "
            f"used_jax={st.used_jax}]")
    out += (f"\n[claim check: lockstep bit-identical to scalar on "
            f"{len(specs)} cells (aggregate sha {agg_sha[:12]}...); "
            f"no-jax deferred path bit-identical on "
            f"{len(nojax_specs)} cells]")

    floor = (MIN_LOCKSTEP_FILL_SPEEDUP if write
             else MIN_LOCKSTEP_FILL_SPEEDUP / 2)
    if st.used_jax:
        assert fill_speedup >= floor, \
            f"lockstep fill path only {fill_speedup:.2f}x the scalar " \
            f"allocator (need >= {floor:.1f}x)"
        out += (f"\n[claim check: batched fill path {fill_speedup:.2f}x "
                f"the scalar allocator (floor {floor:.1f}x)]")
    else:  # pragma: no cover - environment without jax
        out += "\n(jax unavailable: fill-throughput gate skipped)"

    if write and st.used_jax:
        _merge_key(JSON_PATH, "lockstep", {
            "hosts_per_pod": list(LOCKSTEP_HOSTS_PER_POD),
            "n_jobs": LOCKSTEP_N_JOBS, "n_seeds": n_seeds,
            "n_cells": len(specs), "gang_size": ex.gang_size,
            "scalar_fill_s": s_fill, "scalar_fills": s_fills,
            "lockstep_fill_s": st.fill_s, "problems": st.problems,
            "epochs": st.epochs, "batches": st.batches,
            "inline_small": st.inline_small,
            "fill_speedup": fill_speedup,
            "scalar_wall_s": s_wall, "lockstep_wall_s": st.wall_s,
            "pool_wall_s": pool_wall, "pool_workers": n_pool,
            "aggregate_sha256": agg_sha})
        out += (f"\n\n[merged lockstep block into "
                f"{os.path.basename(JSON_PATH)}]")
    elif not HAVE_JAX:  # pragma: no cover
        out += "\n(jax unavailable: lockstep block not written)"
    return out


if __name__ == "__main__":
    print(run())
