"""§4.1 job classification: Eq. 3 (RH/MH), Eq. 4 (small/large), the FP
registry (Fig. 4 lines 1-6), and the web/non-web input classifier."""
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (FpRegistry, Job, JobClassifier, JobKind,
                        VirtualCluster, classify_input_type)


def mk_job(m, fp=1.0, name="j", input_type="web"):
    return Job(name=name, code_key=name, input_type=input_type,
               shard_ids=[f"{name}/B{i}" for i in range(m)],
               shard_bytes=[128.0] * m, true_fp=fp)


def test_unknown_until_profiled():
    cluster = VirtualCluster([15, 15])
    reg = FpRegistry()
    clf = JobClassifier(cluster, reg)
    job = mk_job(8, fp=3.0)
    assert clf.classify(job) is JobKind.UNKNOWN
    reg.record(job, 3.0)
    assert clf.classify(mk_job(8, name="j")) is JobKind.SMALL_RH


def test_eq3_rh_vs_mh_boundary():
    """td = k/(k-1) = 2 for two pods; FP just above/below classifies RH/MH."""
    cluster = VirtualCluster([15, 15])
    reg = FpRegistry()
    clf = JobClassifier(cluster, reg)
    for fp, expect in ((2.01, JobKind.SMALL_RH), (2.0, JobKind.SMALL_MH),
                       (1.2, JobKind.SMALL_MH)):
        name = f"job{fp}"
        j = mk_job(8, fp=fp, name=name)
        reg.record(j, fp)
        assert clf.classify(j) is expect, fp


def test_eq4_small_vs_large():
    cluster = VirtualCluster([15, 15])   # N_avg_VPS = 15
    reg = FpRegistry()
    clf = JobClassifier(cluster, reg)
    small = mk_job(15, name="s")
    large = mk_job(16, name="l")
    for j in (small, large):
        reg.record(j, 1.0)
    assert clf.classify(small) is JobKind.SMALL_MH
    assert clf.classify(large) is JobKind.LARGE


@given(m=st.integers(1, 100), fp=st.floats(0, 10),
       pods=st.lists(st.integers(1, 40), min_size=2, max_size=6))
@settings(max_examples=200, deadline=None)
def test_classification_total(m, fp, pods):
    """Every profiled job lands in exactly one of the three classes."""
    cluster = VirtualCluster(pods)
    reg = FpRegistry()
    clf = JobClassifier(cluster, reg)
    j = mk_job(m, fp=fp, name=f"j{m}_{fp}")
    reg.record(j, fp)
    kind = clf.classify(j)
    n_avg = sum(pods) / len(pods)
    if m <= n_avg:
        assert kind in (JobKind.SMALL_MH, JobKind.SMALL_RH)
        assert (kind is JobKind.SMALL_RH) == (fp > cluster.k /
                                              (cluster.k - 1))
    else:
        assert kind is JobKind.LARGE


def test_fp_registry_running_average_and_storage():
    reg = FpRegistry()
    j = mk_job(4, name="wc")
    reg.record(j, 1.0)
    reg.record(j, 2.0)
    assert reg.fp_of(j) == pytest.approx(1.5)
    assert reg.storage_bytes == 20  # one record, ~20 bytes (paper §6.3)


def test_input_type_classifier():
    web = "<page><title>X</title><revision><text>hello</text></revision>"
    txt = "the quick brown fox jumps over the lazy dog " * 20
    assert classify_input_type(web) == "web"
    assert classify_input_type(txt) == "non-web"
    assert classify_input_type("") == "non-web"
