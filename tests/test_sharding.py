"""Logical-axis partitioning rules: divisibility fallback, conflicts,
missing mesh axes, ZeRO-1 state axes, and the hint() no-op contract."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec
from repro.sharding import (DEFAULT_RULES, Rules, hint, logical_to_spec,
                            mesh_axis_size, use_rules)
from repro.train.optimizer import zero1_leaf_axes


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh: divisibility is trivially satisfied; semantic checks
    # against multi-axis meshes use a fake mesh-like below.
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Mesh stand-in with arbitrary axis sizes (no devices needed)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_divisibility_fallback():
    m = FakeMesh(data=16, model=16)
    spec = logical_to_spec(m, DEFAULT_RULES, ("vocab", "embed"),
                           (49155, 2048))
    assert spec == P()  # 49155 % 16 != 0 -> replicate; embed -> None
    spec2 = logical_to_spec(m, DEFAULT_RULES, ("vocab", "embed"),
                            (49408, 2048))
    assert spec2 == P("model")


def test_axis_conflict_drops_later_dim():
    m = FakeMesh(pod=2, data=16, model=16)
    rules = DEFAULT_RULES.updated(embed="data")
    # batch takes (pod, data); embed -> data conflicts -> dropped
    spec = logical_to_spec(m, rules, ("batch", "seq", "embed"),
                           (256, 4096, 2048))
    assert spec == P(("pod", "data"))


def test_missing_mesh_axis_dropped():
    m = FakeMesh(data=16, model=16)  # no 'pod'
    spec = logical_to_spec(m, DEFAULT_RULES, ("batch", None),
                           (256, 128))
    assert spec == P("data")


def test_mesh_axis_size():
    m = FakeMesh(pod=2, data=16, model=16)
    assert mesh_axis_size(m, None) == 1
    assert mesh_axis_size(m, "data") == 16
    assert mesh_axis_size(m, ("pod", "data")) == 32
    assert mesh_axis_size(m, "absent") == 1


def test_zero1_axes_adds_fsdp_on_largest_free_dim():
    m = FakeMesh(data=16, model=16)
    spec = ParamSpec((48, 5120, 2048), jnp.bfloat16, "scaled",
                     ("layers", "embed", "qkv"))
    # qkv -> model; embed -> None by default; fsdp(data) goes on dim 1
    axes = zero1_leaf_axes(spec, m, DEFAULT_RULES)
    assert axes == ("layers", "fsdp", "qkv")


def test_zero1_axes_no_double_data():
    m = FakeMesh(data=16, model=16)
    rules = DEFAULT_RULES.updated(embed="data")
    spec = ParamSpec((48, 5120, 2048), jnp.bfloat16, "scaled",
                     ("layers", "embed", "qkv"))
    # embed already maps to data -> zero1 must not add fsdp again
    axes = zero1_leaf_axes(spec, m, rules)
    assert axes == ("layers", "embed", "qkv")


def test_hint_is_noop_outside_rules(mesh):
    x = jnp.ones((4, 4))
    y = hint(x, ("batch", "embed"))
    assert y is x


def test_hint_constrains_inside_rules(mesh):
    x = jnp.ones((4, 4))
    with use_rules(mesh, DEFAULT_RULES):
        y = jax.jit(lambda a: hint(a, ("batch", "embed")))(x)
    assert y.shape == (4, 4)


def test_rules_updated_immutably():
    r2 = DEFAULT_RULES.updated(seq="model")
    assert DEFAULT_RULES.get("seq") is None
    assert r2.get("seq") == "model"
