"""GLA chunked recurrence vs the sequential oracle — property-swept."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.recurrence import gla_chunked, gla_ref, gla_step


def rand_inputs(B, T, H, K, V, seed=0, decay_strength=1.0):
    rng = np.random.RandomState(seed)
    r = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, T, H, K), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, T, H, V), jnp.float32)
    logw = -jnp.exp(jnp.asarray(
        rng.randn(B, T, H, K) * decay_strength, jnp.float32).clip(-4, 2))
    u = jnp.asarray(rng.randn(H, K), jnp.float32) * 0.1
    return r, k, v, logw, u


@given(B=st.integers(1, 3), T=st.sampled_from([8, 32, 64, 96]),
       H=st.integers(1, 3), K=st.sampled_from([4, 16]),
       V=st.sampled_from([4, 8]), chunk=st.sampled_from([8, 16, 32]),
       use_u=st.booleans(), seed=st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_chunked_matches_sequential(B, T, H, K, V, chunk, use_u, seed):
    if T % chunk:
        chunk = T
    r, k, v, logw, u = rand_inputs(B, T, H, K, V, seed)
    u = u if use_u else None
    y_ref, s_ref = gla_ref(r, k, v, logw, u)
    y, s = gla_chunked(r, k, v, logw, u, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(s, s_ref, atol=5e-4, rtol=1e-3)


def test_extreme_decay_is_stable():
    """Very strong decay (w -> 0) must not produce inf/nan — the chunked
    path's exponents are all <= 0 by construction."""
    B, T, H, K, V = 1, 64, 2, 8, 8
    r, k, v, _, u = rand_inputs(B, T, H, K, V, seed=3)
    logw = jnp.full((B, T, H, K), -60.0)  # decay ~ e^-60 per step
    y, s = gla_chunked(r, k, v, logw, u, chunk=32)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(s)))
    y_ref, s_ref = gla_ref(r, k, v, logw, u)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-3)


def test_no_decay_reduces_to_linear_attention():
    """logw = 0 (w = 1): the state is a plain cumulative sum of k^T v."""
    B, T, H, K, V = 1, 16, 1, 4, 4
    r, k, v, _, _ = rand_inputs(B, T, H, K, V, seed=4)
    logw = jnp.zeros((B, T, H, K))
    y, s = gla_chunked(r, k, v, logw, None, chunk=8)
    s_expect = jnp.einsum("bthk,bthv->bhkv", k, v)
    np.testing.assert_allclose(s, s_expect, atol=1e-4, rtol=1e-3)


def test_initial_state_carries():
    """Splitting a sequence in half and carrying the state must equal the
    one-shot computation (the decode-consistency primitive)."""
    B, T, H, K, V = 2, 64, 2, 8, 8
    r, k, v, logw, u = rand_inputs(B, T, H, K, V, seed=7)
    y_full, s_full = gla_chunked(r, k, v, logw, u, chunk=16)
    y1, s1 = gla_chunked(r[:, :32], k[:, :32], v[:, :32], logw[:, :32],
                         u, chunk=16)
    y2, s2 = gla_chunked(r[:, 32:], k[:, 32:], v[:, 32:], logw[:, 32:],
                         u, chunk=16, initial_state=s1)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), y_full, atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(s2, s_full, atol=5e-4, rtol=1e-3)
