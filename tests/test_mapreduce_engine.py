"""JAX MapReduce engine vs a Python-dict oracle + FP measurements
(reproducing the paper's Figs. 1-2 qualitative structure)."""
import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.mapreduce import JOBS, corpus, local_mapreduce, measure_fp
from repro.mapreduce.jobs import EMPTY, word_len


def python_wordcount(tokens):
    c = collections.Counter(int(t) for t in tokens if t >= 0)
    return c


def test_wordcount_matches_python_oracle():
    tok, lng = corpus("non-web", 2048, seed=1)
    k, v, n = local_mapreduce(JOBS["WC"], jnp.asarray(tok),
                              jnp.asarray(lng))
    got = {int(kk): int(vv) for kk, vv in zip(np.asarray(k), np.asarray(v))
           if kk != EMPTY}
    expect = python_wordcount(tok)
    assert got == dict(expect)
    assert int(n) == len(expect)


def test_grep_counts_pattern_occurrences():
    from repro.mapreduce.jobs import grep_map_factory, MapReduceSpec
    tok, lng = corpus("web", 1024, seed=2)
    pattern = int(tok[10])
    spec = MapReduceSpec("Grep", grep_map_factory(pattern), 1, False)
    k, v, n = local_mapreduce(spec, jnp.asarray(tok), jnp.asarray(lng))
    assert int(v.sum()) == int((tok == pattern).sum())


def test_fp_depends_on_input_type():
    """Paper Figs. 1-2: FP of a benchmark differs by input type, and Grep
    FP << WC FP <= Permu FP ~= 3."""
    tok_w, lng_w = corpus("web", 8192, seed=3)
    tok_t, lng_t = corpus("non-web", 8192, seed=4)
    fps = {}
    for name in ("WC", "SC", "Grep", "Permu"):
        fw = float(measure_fp(JOBS[name], tok_w[None], lng_w[None])[0])
        ft = float(measure_fp(JOBS[name], tok_t[None], lng_t[None])[0])
        fps[name] = (fw, ft)
    assert fps["Grep"][0] < 0.2
    assert fps["Permu"][0] == pytest.approx(3.0, abs=0.2)
    assert fps["Permu"][1] == pytest.approx(3.0, abs=0.2)
    # web vs non-web FP differs markedly for WC (markup length effect)
    assert abs(fps["WC"][0] - fps["WC"][1]) > 0.1


def test_fp_stable_across_shards():
    """Paper §4.1: per-shard FP std is small relative to the mean for a
    fixed input type -> the averaged-FP reduction (Eq. 2) is sound."""
    shards_t, shards_l = [], []
    for s in range(8):
        t, l = corpus("web", 4096, seed=100 + s)
        shards_t.append(t)
        shards_l.append(l)
    fps = measure_fp(JOBS["WC"], np.stack(shards_t), np.stack(shards_l))
    assert float(np.std(fps)) < 0.15 * float(np.mean(fps))


def test_word_len_deterministic_and_typed():
    ids = np.array([1, 1, 70, 70, 200], np.int32)
    l1, l2 = word_len(ids), word_len(ids)
    np.testing.assert_array_equal(l1, l2)
    assert l1[0] == l1[1]
    # markup ids are long on average (paper Table 2 vs Table 4)
    markup = word_len(np.arange(0, 64, dtype=np.int32)).mean()
    content = word_len(np.arange(64, 4096, dtype=np.int32)).mean()
    assert markup > content
