"""Per-architecture smoke tests (reduced same-family configs, real CPU
fwd/train step) + the decode==forward consistency invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.models import build_model

ARCH_NAMES = sorted(ARCHS)


def make_batches(cfg, B=2, S=32, seed=1):
    toks = jnp.asarray(
        np.random.RandomState(seed).randint(0, cfg.vocab, (B, S + 1)),
        jnp.int32)
    batch = {"tokens": toks[:, :S]}
    full = {"tokens": toks}
    if cfg.family == "encdec":
        fr = jnp.asarray(np.random.RandomState(2).randn(
            B, S, cfg.frontend_dim), jnp.float32)
        batch["frames"] = fr
        full["frames"] = fr
    if cfg.family == "vlm":
        pt = jnp.asarray(np.random.RandomState(3).randn(
            B, cfg.vis_tokens, cfg.vis_dim), jnp.float32)
        batch["patches"] = pt
        full["patches"] = pt
    return batch, full, toks


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_loss(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, _, _ = make_batches(cfg)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))
    logits = model.forward(params, batch, remat=False)
    assert logits.shape[0] == 2
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    """prefill(S tokens) + decode_step(token S) == forward(S+1 tokens)."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch, full, toks = make_batches(cfg, B=B, S=S)
    vis = cfg.vis_tokens if cfg.family == "vlm" else 0
    logits_full = model.forward(params, full, remat=False)
    logits_pf, cache = model.prefill(params, batch, cache_len=S + vis + 4)
    pos = S + vis
    lg, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                              jnp.int32(pos))
    err = float(jnp.max(jnp.abs(logits_full[:, pos] - lg[:, 0])))
    err_pf = float(jnp.max(jnp.abs(logits_full[:, pos - 1]
                                   - logits_pf[:, -1])))
    assert err < 2e-2, (arch, err)
    assert err_pf < 2e-2, (arch, err_pf)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_two_decode_steps_consistent(arch):
    """Decoding two tokens sequentially matches the forward oracle."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jnp.asarray(np.random.RandomState(5).randint(
        0, cfg.vocab, (B, S + 2)), jnp.int32)
    batch = {"tokens": toks[:, :S]}
    full = {"tokens": toks}
    if cfg.family == "encdec":
        fr = jnp.asarray(np.random.RandomState(2).randn(
            B, S, cfg.frontend_dim), jnp.float32)
        batch["frames"] = fr
        full["frames"] = fr
    if cfg.family == "vlm":
        pt = jnp.asarray(np.random.RandomState(3).randn(
            B, cfg.vis_tokens, cfg.vis_dim), jnp.float32)
        batch["patches"] = pt
        full["patches"] = pt
    vis = cfg.vis_tokens if cfg.family == "vlm" else 0
    ref = model.forward(params, full, remat=False)
    _, cache = model.prefill(params, batch, cache_len=S + vis + 4)
    lg1, cache = model.decode_step(params, cache, toks[:, S:S + 1],
                                   jnp.int32(S + vis))
    lg2, cache = model.decode_step(params, cache, toks[:, S + 1:S + 2],
                                   jnp.int32(S + vis + 1))
    assert float(jnp.max(jnp.abs(ref[:, S + vis] - lg1[:, 0]))) < 2e-2
    assert float(jnp.max(jnp.abs(ref[:, S + vis + 1] - lg2[:, 0]))) < 2e-2


def test_shape_cells_cover_assignment():
    """The four assigned cells exist with the exact assigned sizes."""
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long500k_skips_are_subquadratic_only():
    """Every full-attention arch skips long_500k; SSM/hybrid run it."""
    for name, cfg in ARCHS.items():
        if name in ("rwkv6-7b", "hymba-1.5b"):
            assert cfg.supports("long_500k"), name
        else:
            assert not cfg.supports("long_500k"), name


def test_exact_assigned_configs():
    """Spot-check the assigned architecture hyperparameters."""
    q = get_config("qwen2.5-14b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads,
            q.d_ff, q.vocab) == (48, 5120, 40, 8, 13824, 152064)
    assert q.qkv_bias
    a = get_config("arctic-480b")
    assert (a.n_experts, a.moe_topk, a.moe_dense_residual) == (128, 2, True)
    d = get_config("dbrx-132b")
    assert (d.n_experts, d.moe_topk) == (16, 4)
    h = get_config("hymba-1.5b")
    assert (h.ssm_state, h.n_heads, h.n_kv_heads) == (16, 25, 5)
    r = get_config("rwkv6-7b")
    assert (r.n_layers, r.d_model, r.vocab) == (32, 4096, 65536)
    w = get_config("whisper-medium")
    assert (w.encoder_layers, w.n_layers, w.d_model) == (24, 24, 1024)
    i = get_config("internvl2-26b")
    assert (i.vis_tokens, i.vis_dim, i.vocab) == (256, 3200, 92553)
