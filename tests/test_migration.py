"""Graceful preemption (PR 6): notice windows, draining, live migration,
output evacuation, fleet compaction — and the satellite fixes that ride
along (scale-out re-planning, the stale-scale-in-victim race).

The race matrix is first-class: notice-then-finish (stale landing),
notice-then-kill-anyway (src_lost, bit-identical fallback), second
failure mid-transfer (dst_lost), vetoed/renewed kills (survived,
undrain), and same-timestamp notice/kill ordering for all five
algorithms. Scenario seeds below were chosen because they provably
exercise the named path (asserted on the decision log), not by luck.
"""
from collections import Counter

import pytest

from repro.core.job import MapTask, ReduceTask
from repro.core.joss import make_algorithm
from repro.core.queues import ClusterQueues
from repro.core.topology import HostId, VirtualCluster
from repro.elastic import (Autoscaler, BacklogThresholdScaler, ChurnConfig,
                           ChurnEvent, ChurnModel, CompactingScaler,
                           DurabilityConfig, ElasticEngine, FixedFleet,
                           FleetObservation, MigrationConfig, ScaleDecision)
from repro.elastic.migration import MigrationSubsystem, _Pending
from repro.sim.cluster_sim import SimConfig, Simulator
from repro.sim.workloads import make_cluster, profiling_prelude, \
    small_workload

from benchmarks.bench_migration import GATE, migration_probe

ALGOS = ("joss-t", "joss-j", "fifo", "fair", "capacity")


# --------------------------------------------------------------- helpers --
def chaos_run(algo_name, seed, churn_kw, *, scaler=None, mig_kw=None,
              slow=6.0, n_jobs=24, hosts_per_pod=(4, 4)):
    """One elastic run with migration attached, uniform-slow fleet."""
    cluster = make_cluster(hosts_per_pod)
    jobs = small_workload(cluster, seed=seed, n_jobs=n_jobs)
    algo = make_algorithm(algo_name, cluster)
    if hasattr(algo, "registry"):
        for j in profiling_prelude(cluster):
            algo.registry.record(j, j.true_fp)
    slow_hosts = {HostId(p, i): slow
                  for p, n in enumerate(hosts_per_pod) for i in range(n)}
    eng = ElasticEngine(cluster, churn=ChurnConfig(seed=seed + 1,
                                                   **churn_kw),
                        autoscaler=scaler or FixedFleet(),
                        migration=MigrationConfig(**(mig_kw or {})))
    res = Simulator(cluster, algo, jobs,
                    config=SimConfig(slow_hosts=slow_hosts),
                    seed=seed, elastic=eng).run()
    assert len(res.job_finish) == len(jobs)
    return res


def abort_reasons(ms) -> Counter:
    return Counter(d[-1] for d in ms.decision_log
                   if d[1] in ("abort", "out_abort"))


def trajectory(res):
    idx = {j.job_id: i for i, j in enumerate(res.jobs)}
    return (res.wtt, res.n_reexec, res.work_lost_mb,
            tuple(((log.task.tid[0], idx[log.task.tid[1]],
                    *log.task.tid[2:]),
                   (log.host.pod, log.host.index),
                   log.start, log.finish) for log in res.task_logs))


# ------------------------------------------------- notice events (churn) --
def test_notice_placed_exactly_window_before_kill_no_rng():
    model = ChurnModel(ChurnConfig(seed=3, preempt_notice=30.0,
                                   expire_notice=120.0))
    state = model.rng.get_state()[1].copy()
    kill = ChurnEvent(500.0, "preempt", 0, 2)
    n = model.notice_for(kill, now=0.0)
    assert (n.time, n.kind, n.target, n.deadline) == (470.0, "notice",
                                                      "preempt", 500.0)
    exp = model.notice_for(ChurnEvent(500.0, "expire", 1, 0), now=0.0)
    assert exp.time == 380.0 and exp.target == "expire"
    # derived events consume no RNG draws: kill times never move
    assert (model.rng.get_state()[1] == state).all()


def test_notice_clamps_to_now_and_skips_unannounced_kinds():
    model = ChurnModel(ChurnConfig(seed=3, preempt_notice=300.0))
    late = model.notice_for(ChurnEvent(100.0, "preempt", 0, 0), now=50.0)
    assert late.time == 50.0 and late.deadline == 100.0
    assert model.notice_for(ChurnEvent(100.0, "fail", 0, 0), 0.0) is None
    assert model.notice_for(ChurnEvent(100.0, "join", 0, None), 0.0) is None
    zero = ChurnModel(ChurnConfig(seed=3))     # window 0 = the default
    assert zero.notice_for(ChurnEvent(100.0, "preempt", 0, 0), 0.0) is None


# ------------------------------------------- the claims probe, per algo --
@pytest.mark.parametrize("name", ALGOS)
def test_migration_saves_work_on_the_gate_scenario(name):
    """The acceptance criterion, standalone: on the committed gate
    scenario the kill+requeue baseline loses real work; migration holds
    the loss to <= 5% of it and strictly cuts re-executions."""
    base = migration_probe(name, migrate=False)
    mig = migration_probe(name, migrate=True)
    assert base.work_lost_mb > 0
    assert mig.work_lost_mb <= 0.05 * base.work_lost_mb
    assert mig.n_reexec < base.n_reexec
    ms = mig.migration
    # evacuation is what closes the finished-output loss channel
    assert ms.n_out_moved > 0 and ms.out_mb > 0
    assert mig.migrate_mb == pytest.approx(ms.state_mb + ms.out_mb)


@pytest.mark.parametrize("name", ALGOS)
def test_zero_notice_window_is_inert(name):
    """Migration enabled but never warned must be bit-identical to the
    no-migration elastic run (the subsystem acts only inside windows)."""
    a = migration_probe(name, migrate=False, notice=0.0)
    b = migration_probe(name, migrate=True, notice=0.0)
    assert trajectory(a) == trajectory(b)
    assert b.migration.n_notices == 0 and b.migration.decision_log == []


@pytest.mark.parametrize("name", ALGOS)
def test_near_zero_notice_orders_like_the_kill_itself(name):
    """Same-timestamp ordering: a vanishingly small window delivers the
    notice essentially *at* the kill. Nothing can ship in time, so the
    kill must requeue bit-identically to the windowless run for every
    algorithm — the notice-then-kill-anyway race degrades to today's
    behaviour, it never perturbs the trajectory."""
    bare = migration_probe(name, migrate=True, notice=0.0)
    tiny = migration_probe(name, migrate=True, notice=1e-9)
    assert trajectory(tiny) == trajectory(bare)
    ms = tiny.migration
    assert ms.n_notices > 0          # the warnings did arrive
    assert ms.n_migrated == 0        # but nothing could land in 1 ns
    started = ms.n_started + len(
        [d for d in ms.decision_log if d[1] == "out_start"])
    assert abort_reasons(ms).get("src_lost", 0) \
        + abort_reasons(ms).get("host_lost", 0) == started


def test_restored_tasks_are_flagged_and_excluded_from_reexec():
    res = migration_probe("fifo", migrate=True)
    migrated = [log for log in res.task_logs if log.migrated]
    # completed flagged attempts can undercount n_migrated: a restored
    # attempt may itself be killed by later churn before finishing
    assert 0 < len(migrated) <= res.n_migrated
    # a restored attempt resumes, it is not a forced re-execution
    assert res.n_reexec < migration_probe("fifo", migrate=False).n_reexec


def test_migration_decisions_deterministic_per_seed():
    a = migration_probe("capacity", migrate=True)
    b = migration_probe("capacity", migrate=True)
    assert a.migration.signature() == b.migration.signature()
    assert trajectory(a) == trajectory(b)


# --------------------------------------------------------- race matrix --
def test_short_window_chaos_hits_src_lost_and_inflight_evac_kill():
    """Seed 11, 8 s windows: transfers are caught mid-flight by the
    announced kill (src_lost) and by a second kill of the evacuation
    source (host_lost) — both drop the transfer, neither corrupts the
    run (every job still finishes, asserted in the helper)."""
    res = chaos_run("joss-t", 11, dict(spot_fraction=0.5,
                                       spot_preempt_rate=10.0,
                                       preempt_notice=8.0))
    whys = abort_reasons(res.migration)
    assert whys["src_lost"] >= 1 and whys["host_lost"] >= 1


def test_stale_landing_abandoned():
    """Lease-expiry scenario where the state lands after its purpose
    evaporated (source attempt finished / reduces drained): the landing
    is abandoned, nothing is restored twice."""
    res = chaos_run("joss-t", 11, dict(lease_term=600.0,
                                       expire_notice=120.0),
                    scaler=BacklogThresholdScaler(min_hosts=2))
    assert abort_reasons(res.migration)["stale"] >= 1


class FlipFlopRenewal(Autoscaler):
    """Refuses renewal when asked at notice time, renews at the actual
    expiry — the announced kill never lands, forcing the survived path."""

    name = "flipflop"

    def __init__(self):
        self.calls = {}

    def renew_lease(self, hid, kind, obs):
        n = self.calls.get(hid, 0)
        self.calls[hid] = n + 1
        return n % 2 == 1


def test_renewed_expiry_survives_undrains_and_aborts_transfers():
    res = chaos_run("joss-t", 3, dict(lease_term=500.0,
                                      expire_notice=2.0),
                    scaler=FlipFlopRenewal())
    ms = res.migration
    assert ms.n_notices > 0
    assert abort_reasons(ms)["survived"] >= 1
    # every announced expiry was renewed: the fleet never shrank, and no
    # drain outlived its (cancelled) kill
    assert res.n_host_losses == 0


class _FakeCluster:
    def has_host(self, hid):
        return True


def _fake_sim():
    class S:
        pass
    s = S()
    s.jobs = []
    s.departed = set()
    s.draining = set()
    s.map_free = {}
    s.red_free = {}
    s.free_map_hosts = set()
    s.free_red_hosts = set()
    s.host_outputs = {}
    s.fabric = None
    s.cluster = _FakeCluster()
    return s


def test_losing_the_destination_cancels_transfer_keeps_source():
    """Second-failure race, driven directly: only an *unannounced* kill
    can reach a transfer destination (announced ones drain the host out
    of the candidate sets first — see the structural test below), so the
    hook is exercised against a hand-built pending transfer."""
    ms = MigrationSubsystem(MigrationConfig())
    sim = _fake_sim()
    ms.sim = sim
    src, dst = HostId(0, 0), HostId(1, 1)
    sim.map_free = {src: 1, dst: 0}
    tid = ("M", 5, 0, 0)
    ms.pending[tid] = _Pending(tid, src, dst, 0.4, 50.0, -1,
                               "preempt", True)

    class H:
        hid = dst
    ms.on_host_lost(H, 100.0)
    assert ms.pending == {}
    assert abort_reasons(ms.summary)["dst_lost"] == 1
    # the source attempt is untouched: its slot books were never touched
    assert sim.map_free[src] == 1


def test_announced_kills_never_select_a_doomed_destination():
    """Structural guarantee behind the unit test above: with announced
    preemptions only, a host due to die is draining by the time any
    transfer picks destinations, so dst_lost can never occur."""
    for seed in (1, 4, 5, 10):
        res = chaos_run("joss-t", seed,
                        dict(spot_fraction=0.6, spot_preempt_rate=20.0,
                             preempt_notice=10.0),
                        mig_kw=dict(state_base_mb=400.0, mig_bw=8.0,
                                    evac_outputs=False))
        ms = res.migration
        assert ms.n_started >= 1        # transfers were in flight...
        assert abort_reasons(ms)["dst_lost"] == 0   # ...none dst-raced


# ------------------------------------------------------------ compaction --
def _obs(now=0.0, n_hosts=6, backlog=0, idle=(), light=()):
    return FleetObservation(now=now, n_hosts=n_hosts, map_backlog=backlog,
                            red_backlog=0, busy_hosts=n_hosts - len(idle),
                            cost=0.0, vps_hours=0.0,
                            idle_hosts=tuple(idle),
                            light_hosts=tuple(light))


def test_compacting_scaler_gates_removals_on_prior_drains():
    sc = CompactingScaler(interval=30.0, hi=4.0, step=2, min_hosts=2,
                          cooldown=0.0)
    idle = (HostId(0, 0),)
    light = (HostId(1, 0), HostId(1, 1))
    # tick 1: nothing drained yet -> no removals, drains requested
    # (idle disks may hold outputs too: idle hosts drain, not die cold)
    d1 = sc.decide(_obs(now=0.0, idle=idle, light=light))
    assert d1.remove == () and d1.drain == (HostId(0, 0), HostId(1, 0))
    # tick 2: the drained-idle host may now be removed; draining is
    # requested at most once per host, so fresh candidates fill the step
    d2 = sc.decide(_obs(now=60.0, idle=idle, light=light))
    assert d2.remove == idle
    assert HostId(1, 1) in d2.drain and HostId(1, 0) not in d2.drain


def test_compacting_scaler_is_plain_backlog_scaler_under_pressure():
    sc = CompactingScaler(interval=30.0, hi=1.0, step=2, min_hosts=2,
                          cooldown=0.0)
    d = sc.decide(_obs(backlog=40, light=(HostId(0, 0),)))
    assert d.add == 2 and d.drain == () and d.remove == ()


def test_compaction_run_releases_leases_without_losing_work():
    def one(compact):
        cluster = make_cluster((6, 6))
        jobs = small_workload(cluster, seed=11, n_jobs=16)
        for j in jobs:
            j.submit_time = 0.0
        algo = make_algorithm("fifo", cluster)
        kw = dict(interval=30.0, hi=4.0, step=4, min_hosts=2)
        eng = ElasticEngine(
            cluster, churn=None,
            autoscaler=CompactingScaler(**kw) if compact
            else BacklogThresholdScaler(**kw),
            durability=DurabilityConfig(checkpoint=True),
            migration=MigrationConfig())
        slow = {HostId(0, 1): 8.0, HostId(0, 3): 8.0, HostId(1, 2): 8.0}
        res = Simulator(cluster, algo, jobs,
                        config=SimConfig(slow_hosts=slow),
                        seed=11, elastic=eng).run()
        assert len(res.job_finish) == len(jobs)
        return res

    base, comp = one(False), one(True)
    assert base.work_lost_mb == comp.work_lost_mb == 0.0
    assert comp.n_migrated > 0                 # stragglers moved off
    assert comp.vps_hours < base.vps_hours     # leases released earlier


# ------------------------------- satellite: scale-out re-planning (opt-in) --
def mk_map(job_id, index, shard):
    return MapTask(job_id, index, shard, 128)


def test_rebalance_to_pod_pulls_from_most_backlogged_donor_tail():
    cluster = VirtualCluster([2, 2, 2])
    queues = ClusterQueues(cluster)
    p1 = [mk_map(1, i, f"a{i}") for i in range(2)]
    p2 = [mk_map(2, i, f"b{i}") for i in range(4)]
    queues.pods[1].mq0.extend(p1)
    queues.pods[2].mq0.extend(p2)
    moved = queues.rebalance_to_pod(0, 3)
    assert moved == 3
    # donor = pod 2 (deepest backlog); tasks leave its queue tail so the
    # donor's own hosts keep draining the FIFO head undisturbed
    assert list(queues.pods[0].mq0) == p2[1:]
    assert list(queues.pods[2].mq0) == p2[:1]
    assert list(queues.pods[1].mq0) == p1
    assert queues.rebalance_to_pod(0, 0) == 0


def test_rebalance_to_pod_without_donors_is_a_noop():
    queues = ClusterQueues(VirtualCluster([2, 2]))
    assert queues.rebalance_to_pod(0, 4) == 0


def test_host_added_replan_is_opt_in():
    """Default off: joins must not move queued work (the committed churn
    goldens replay rejoin joins and their trajectories pin this). On:
    a join into a workless pod pulls maps from the busiest other pod."""
    def mk(replan):
        cluster = VirtualCluster([2, 2])
        algo = make_algorithm("joss-t", cluster,
                              replan_on_scaleout=replan) \
            if replan else make_algorithm("joss-t", cluster)
        q = algo.scheduler.queues
        q.pods[1].mq0.extend(mk_map(1, i, f"s{i}") for i in range(5))
        return algo, q

    algo, q = mk(False)
    algo.host_added(HostId(0, 0))
    assert q.pods[0].map_load.n == 0 and q.pods[1].map_load.n == 5

    algo, q = mk(True)
    algo.host_added(HostId(0, 0))
    # pulls 2 * map_slots toward the newcomer's pod
    slots = algo.cluster.host(HostId(0, 0)).map_slots
    assert q.pods[0].map_load.n == 2 * slots
    assert q.pods[1].map_load.n == 5 - 2 * slots
    # a pod that already has work attracts nothing more
    algo.host_added(HostId(0, 1))
    assert q.pods[0].map_load.n == 2 * slots


def test_replan_on_scaleout_full_run_completes():
    res = chaos_run("joss-t", 7, dict(fail_rate=2.0, rejoin_delay=60.0),
                    slow=2.0, n_jobs=12)
    cluster = make_cluster((4, 4))
    jobs = small_workload(cluster, seed=7, n_jobs=12)
    algo = make_algorithm("joss-t", cluster, replan_on_scaleout=True)
    for j in profiling_prelude(cluster):
        algo.registry.record(j, j.true_fp)
    slow_hosts = {HostId(p, i): 2.0 for p in range(2) for i in range(4)}
    eng = ElasticEngine(cluster,
                        churn=ChurnConfig(seed=8, fail_rate=2.0,
                                          rejoin_delay=60.0),
                        autoscaler=FixedFleet(),
                        migration=MigrationConfig())
    res2 = Simulator(cluster, algo, jobs,
                     config=SimConfig(slow_hosts=slow_hosts),
                     seed=7, elastic=eng).run()
    assert len(res2.job_finish) == len(jobs) == len(res.job_finish)


# ------------------------- satellite: stale scale-in victims (apply race) --
class StaleVictimScaler(Autoscaler):
    """Names a host for scale-in regardless of its occupancy — the
    autoscale observation is always stale by construction."""

    name = "stale"
    interval = 5.0

    def __init__(self, victim):
        self.victim = victim
        self.n_asked = 0

    def decide(self, obs):
        self.n_asked += 1
        return ScaleDecision(remove=(self.victim,))


def test_busy_scale_in_victim_vetoed_at_apply_time():
    """A victim that picked up work between the observation and the
    apply is kept (counted in n_stale_victims), not killed under its
    fresh tasks; once genuinely idle it is released normally."""
    cluster = make_cluster((2, 2))
    jobs = small_workload(cluster, seed=5, n_jobs=8)
    for j in jobs:
        j.submit_time = 0.0      # burst: every host is busy at tick time
    algo = make_algorithm("fifo", cluster)
    victim = HostId(0, 0)
    slow = {h.hid: 4.0 for h in cluster.hosts()}
    scaler = StaleVictimScaler(victim)
    eng = ElasticEngine(cluster, churn=None, autoscaler=scaler,
                        migration=MigrationConfig())
    res = Simulator(cluster, algo, jobs,
                    config=SimConfig(slow_hosts=slow),
                    seed=5, elastic=eng).run()
    assert len(res.job_finish) == len(jobs)
    assert scaler.n_asked > 1
    s = eng.summary
    assert s.n_stale_victims >= 1          # busy picks were vetoed
    # the veto is a keep, not a kill: no task of the victim was killed
    # by scale-in (scale_in losses only ever removed an idle host)
    for t, hid, reason in s.loss_log:
        if reason == "scale_in":
            assert hid == victim
