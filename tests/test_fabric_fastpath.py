"""PR 5 tentpole: the class-aggregated fabric allocator vs the retained
per-flow reference (``repro.sim.network_reference``).

Covers bit-identical completion logs and full simulation signatures
across static/churn/durability/speculative scenarios, allocator-level
equivalence under choreographed start/cancel sequences, same-timestamp
epoch races (cancel-then-complete, complete-then-start), the explicit
``(share, link_key)`` tie-break total order, the starved-flow guard
(zero-capacity elastic links must not divide by zero), elastic link
capacities, and the bounded completion log.
"""
import heapq

import pytest

from repro.core.joss import make_algorithm
from repro.core.topology import ElasticLinks, HostId, LinkCapacities
from repro.sim import golden
from repro.sim.cluster_sim import SimConfig, Simulator
from repro.sim.engine import EventKernel
from repro.sim.network import (DOWN, FCAP, UP, WAN, FabricConfig,
                               NetworkFabric, make_fabric)
from repro.sim.network_reference import ReferenceNetworkFabric
from repro.sim.workloads import (fabric_links, make_cluster,
                                 profiling_prelude, small_workload)

ALLOCATORS = ("fast", "reference")


class _Sim:
    pass


def _bare(links=None, pods=2, *, cfg=None, allocator="fast"):
    cluster = make_cluster((2,) * pods, links=links)
    cfg = cfg or FabricConfig(allocator=allocator)
    fab = make_fabric(cluster, cfg)
    k = EventKernel()
    fab.attach(_Sim(), k)
    return fab, k, cluster


def _bare_pair(links=None, pods=2, **cfg_kw):
    out = []
    for allocator in ALLOCATORS:
        cfg = FabricConfig(allocator=allocator, **cfg_kw)
        out.append(_bare(links, pods, cfg=cfg))
    return out


def _summary_state(fab):
    s = fab.summary
    return (s.n_flows, s.n_cancelled, s.mb_total, s.stall_s, s.by_kind,
            s.completion_log, s.log_dropped)


def test_make_fabric_selects_allocator():
    cluster = make_cluster((2, 2))
    assert isinstance(make_fabric(cluster, FabricConfig()), NetworkFabric)
    assert isinstance(
        make_fabric(cluster, FabricConfig(allocator="reference")),
        ReferenceNetworkFabric)
    with pytest.raises(ValueError):
        make_fabric(cluster, FabricConfig(allocator="bogus"))


# ------------------------------------------------- allocator equivalence --
def _choreograph(fab, k):
    """A deterministic start/cancel script exercising shared classes,
    rebalances, cancels and restarts; returns the completion trace."""
    trace = []

    def done(tag):
        return lambda now: trace.append((tag, now))

    fids = {}
    # three classes: intra-pod 0, cross-pod, external ingress; several
    # members each, mixed caps
    for i in range(6):
        fids[f"a{i}"] = fab.start_flow(0.0, 40.0 + 3.0 * i, 0, 0, 110.0,
                                       "intra", done(f"a{i}"))
    for i in range(5):
        fids[f"b{i}"] = fab.start_flow(0.0, 60.0 + 5.0 * i, 0, 1, 35.0,
                                       "inter", done(f"b{i}"))
    for i in range(3):
        fids[f"c{i}"] = fab.start_flow(0.0, 25.0 + 7.0 * i, None, 1, 35.0,
                                       "ext", done(f"c{i}"))
    # mid-run churn: cancels at staggered instants, a late joiner
    k.call_at(0.4, lambda now: fab.cancel(fids["b3"], now))
    k.call_at(0.9, lambda now: fab.cancel(fids["a5"], now))
    k.call_at(1.3, lambda now: fab.start_flow(now, 80.0, 1, 0, 110.0,
                                              "late", done("late")))
    k.call_at(1.3, lambda now: fab.cancel(fids["c2"], now))
    k.run()
    return trace


def test_choreographed_equivalence_is_bitwise():
    links = LinkCapacities(pod_up=260.0, pod_down=260.0, wan=95.0)
    (fa, ka, _), (fr, kr, _) = _bare_pair(links)
    ta = _choreograph(fa, ka)
    tr = _choreograph(fr, kr)
    assert ta == tr and len(ta) == 12   # 15 started, 3 cancelled
    assert fa.summary.completion_log == fr.summary.completion_log
    assert _summary_state(fa) == _summary_state(fr)
    assert fa.finalize(2.0).link_util == fr.finalize(2.0).link_util


def test_rates_equivalent_after_each_start():
    """After every single start the per-flow rates of the two allocators
    match bitwise (same fid sequence, same rate)."""
    links = LinkCapacities(pod_up=300.0, pod_down=300.0, wan=70.0)
    (fa, _ka, _), (fr, _kr, _) = _bare_pair(links)
    script = [(0, 1, 35.0), (0, 1, 35.0), (0, 0, 110.0), (None, 1, 35.0),
              (1, 0, 35.0), (0, 1, 20.0), (0, 0, 110.0), (1, 1, 110.0)]
    for i, (src, dst, cap) in enumerate(script):
        fa.start_flow(0.0, 50.0 + i, src, dst, cap, "t", lambda n: None)
        fr.start_flow(0.0, 50.0 + i, src, dst, cap, "t", lambda n: None)
        ra = {fid: f.rate for fid, f in fa._flows.items()}
        rr = {fid: f.rate for fid, f in fr._flows.items()}
        assert ra == rr


# --------------------------------------------------- end-to-end bitwise --
def _e2e(allocator, variant, algo_name="joss-t", elastic_links=None):
    from repro.elastic import (ChurnConfig, DurabilityConfig, ElasticEngine,
                               FixedFleet)
    cluster = make_cluster((4, 4), links=fabric_links((4, 4),
                                                      wan_oversub=8.0))
    jobs = small_workload(cluster, seed=11, n_jobs=12)
    algo = make_algorithm(algo_name, cluster)
    if hasattr(algo, "registry"):
        for j in profiling_prelude(cluster):
            algo.registry.record(j, j.true_fp)
    cfg_kw = {}
    elastic = None
    if variant in ("churn", "churn+durability"):
        dur = (DurabilityConfig(rereplicate=True, rerep_delay=5.0,
                                checkpoint=True)
               if variant == "churn+durability" else None)
        elastic = ElasticEngine(
            cluster, churn=ChurnConfig(seed=12, fail_rate=4.0,
                                       rejoin_delay=60.0),
            autoscaler=FixedFleet(), durability=dur)
    elif variant == "speculative":
        cfg_kw = dict(speculative=True, slow_hosts={HostId(0, 0): 4.0})
    cfg = SimConfig(fabric=FabricConfig(allocator=allocator,
                                        elastic=elastic_links), **cfg_kw)
    res = Simulator(cluster, algo, jobs, config=cfg, seed=11,
                    elastic=elastic).run()
    assert len(res.job_finish) == 12
    return res


@pytest.mark.parametrize("variant", ["static", "churn", "churn+durability",
                                     "speculative"])
def test_end_to_end_bit_identity(variant):
    a = _e2e("fast", variant)
    b = _e2e("reference", variant)
    assert a.fabric.completion_log, "scenario produced no flows"
    assert a.fabric.completion_log == b.fabric.completion_log
    assert golden.full_signature(a) == golden.full_signature(b)
    assert a.fabric.link_util == b.fabric.link_util
    assert a.fabric.n_cancelled == b.fabric.n_cancelled


def test_end_to_end_bit_identity_with_elastic_links():
    el = ElasticLinks(host_up=220.0, host_down=220.0, wan_per_host=35.0)
    a = _e2e("fast", "churn+durability", elastic_links=el)
    b = _e2e("reference", "churn+durability", elastic_links=el)
    assert a.fabric.completion_log == b.fabric.completion_log
    assert golden.full_signature(a) == golden.full_signature(b)


# ------------------------------------------- same-timestamp epoch races --
def test_cancel_then_complete_at_same_instant():
    """A cancel processed at exactly a completion's armed time must kill
    the cancelled flow, still complete the finished one, and leave both
    allocators in an identical state (the stale-epoch path)."""
    for allocator in ALLOCATORS:
        fab, k, _ = _bare(LinkCapacities(pod_up=1e6, pod_down=1e6,
                                         wan=100.0), allocator=allocator)
        times = {}
        # the cancel is pushed first so it pops before the flow event
        # armed for the same instant (seq order)
        k.call_at(1.0, lambda now: fab.cancel(fids["b"], now))
        fids = {
            "a": fab.start_flow(0.0, 50.0, 0, 1, 1e6, "t",
                                lambda now: times.setdefault("a", now)),
            "b": fab.start_flow(0.0, 200.0, 0, 1, 1e6, "t",
                                lambda now: times.setdefault("b", now)),
        }
        k.run()
        # both ran at 50 MB/s; a finished exactly when b was cancelled
        assert times == {"a": 1.0}
        assert fab.summary.n_flows == 1 and fab.summary.n_cancelled == 1
        assert fab.summary.completion_log == [(1.0, "t", 50.0)]
        assert not fab._flows


def test_complete_then_start_at_same_instant():
    """A done-callback starting a new flow at the completion instant
    must join/extend classes identically in both allocators."""
    logs = []
    for allocator in ALLOCATORS:
        fab, k, _ = _bare(LinkCapacities(pod_up=1e6, pod_down=1e6,
                                         wan=120.0), allocator=allocator)
        times = {}

        def chain(now, fab=fab, times=times):
            times["first"] = now
            fab.start_flow(now, 30.0, 0, 1, 1e6, "t2",
                           lambda tn: times.setdefault("second", tn))

        fab.start_flow(0.0, 60.0, 0, 1, 1e6, "t1", chain)
        fab.start_flow(0.0, 240.0, 0, 1, 1e6, "t1",
                       lambda now: times.setdefault("long", now))
        k.run()
        # 60/60 split until t=1; the chained 30 MB joins the long flow's
        # class and they split 60/60 until t=1.5; the remaining 150 MB
        # then drain at the full 120
        assert times["first"] == pytest.approx(1.0)
        assert times["second"] == pytest.approx(1.5)
        assert times["long"] == pytest.approx(2.75)
        logs.append(fab.summary.completion_log)
    assert logs[0] == logs[1]


# ---------------------------------------------- starved flows (no /0) --
def test_starved_flow_arms_no_completion_and_resumes():
    """Satellite regression: a flow on a saturated link whose remaining
    capacity is driven to exactly zero (an elastic pod that lost every
    host) must get rate 0.0 and arm *no* completion event — the old
    ``rem / rate`` min-scan raised ZeroDivisionError. When capacity
    returns, the flow resumes and completes."""
    el = ElasticLinks(host_up=100.0, host_down=100.0)
    for allocator in ALLOCATORS:
        fab, k, cluster = _bare(
            cfg=FabricConfig(allocator=allocator, elastic=el))
        done = []
        fab.start_flow(0.0, 100.0, 0, 1, 1e6, "t", done.append)
        # pod 1 provides 2 hosts x 100 MB/s of downlink; the wan (525)
        # and pod-0 uplink (200) leave the flow at 200 MB/s
        assert next(iter(fab._flows.values())).rate == pytest.approx(200.0)
        # half the volume drains by t=0.25, then pod 1 empties: its
        # derived downlink capacity is 0.0 and the flow starves
        for hid in [h.hid for h in cluster.pods[1].hosts]:
            fab.on_host_lost(cluster.remove_host(hid), 0.25)
        assert next(iter(fab._flows.values())).rate == 0.0
        k.run()   # no completion event is armed: nothing fires, no /0
        assert done == [] and len(fab._flows) == 1
        # a host joins pod 1 at t=10: 100 MB/s of downlink comes back
        # and the remaining 50 MB drains in 0.5 s
        fab.on_host_added(cluster.add_host(1).hid, 10.0)
        k.run()
        assert done == [pytest.approx(10.5)]
        assert fab.summary.completion_log[0][0] == pytest.approx(10.5)


def test_idle_gap_accrues_no_phantom_utilization():
    """Regression (latent since PR 4): when the last flow drains, the
    per-link load must zero — an idle gap before the next flow must not
    keep accruing carried MB at the dead flows' rates."""
    links = LinkCapacities(pod_up=1e6, pod_down=1e6, wan=525.0)
    for allocator in ALLOCATORS:
        fab, k, _ = _bare(links, allocator=allocator)
        fab.start_flow(0.0, 100.0, 0, 1, 1e6, "t", lambda n: None)
        k.run()   # drains at t ~= 0.19; the fabric then sits idle
        fab.start_flow(50.0, 100.0, 0, 1, 1e6, "t", lambda n: None)
        k.run()
        s = fab.finalize(51.0)
        assert s.mb_total == pytest.approx(200.0)
        # exactly the 200 MB that physically crossed the WAN
        assert s.link_util["wan"] == pytest.approx(
            200.0 / (525.0 * 51.0))


# -------------------------------------------------- elastic capacities --
def test_elastic_links_track_live_host_count():
    el = ElasticLinks(host_up=50.0, host_down=60.0, wan_per_host=10.0)
    fab, _k, cluster = _bare(cfg=FabricConfig(elastic=el))
    assert fab._caps[(UP, 0)] == pytest.approx(100.0)    # 2 hosts x 50
    assert fab._caps[(DOWN, 1)] == pytest.approx(120.0)
    assert fab._caps[(WAN, 0)] == pytest.approx(40.0)    # 4 hosts x 10
    hid = cluster.add_host(0).hid
    fab.on_host_added(hid, 1.0)
    assert fab._caps[(UP, 0)] == pytest.approx(150.0)
    assert fab._caps[(WAN, 0)] == pytest.approx(50.0)
    fab.on_host_lost(cluster.remove_host(hid), 2.0)
    assert fab._caps[(UP, 0)] == pytest.approx(100.0)
    assert fab._caps[(WAN, 0)] == pytest.approx(40.0)


def test_fixed_links_ignore_churn():
    links = LinkCapacities(pod_up=111.0, pod_down=222.0, wan=333.0)
    fab, _k, cluster = _bare(links)
    before = dict(fab._caps)
    fab.on_host_added(cluster.add_host(0).hid, 1.0)
    assert fab._caps == before


def test_elastic_links_validation():
    with pytest.raises(ValueError):
        ElasticLinks(host_up=0.0)
    with pytest.raises(ValueError):
        ElasticLinks(wan_per_host=-1.0)


# ------------------------------------------------ explicit tie-breaks --
def test_link_key_total_order():
    """Satellite: progressive filling breaks share ties by an explicit
    lexicographic ``(share, link_key)`` minimum. The key space must be
    totally ordered: downlinks < uplinks < the WAN < per-class caps, and
    cap sentinels order among themselves by signature."""
    sig_a = ((("up", 0), ("down", 0)), 35.0)
    sig_b = ((("up", 0), ("down", 0)), 110.0)
    sig_c = ((("up", 0), ("wan", 0), ("down", 1)), 35.0)
    keys = [(FCAP, sig_c), ("wan", 0), ("up", 1), (FCAP, sig_a),
            ("down", 1), ("up", 0), ("down", 0), (FCAP, sig_b)]
    assert sorted(keys) == [
        ("down", 0), ("down", 1), ("up", 0), ("up", 1), ("wan", 0),
        (FCAP, sig_a), (FCAP, sig_b), (FCAP, sig_c)]
    # heap-compatible: every pair is strictly comparable
    heap = list(keys)
    heapq.heapify(heap)
    assert heapq.heappop(heap) == ("down", 0)


def test_share_tie_resolves_to_real_link_and_exact_rate():
    """An exact share tie between a real link and a per-flow cap fixes
    through the real link (caps sort last), and an exactly tied pair of
    real links resolves lexicographically — either way the rate is the
    tied share, bit-exact."""
    fab, _k, _ = _bare(LinkCapacities(pod_up=100.0, pod_down=100.0,
                                      wan=525.0))
    fab.start_flow(0.0, 10.0, 0, 0, 100.0, "t", lambda n: None)
    (f,) = fab._flows.values()
    assert f.rate == 100.0          # up0 == down0 == cap == 100.0
    fab2, _k2, _ = _bare(LinkCapacities(pod_up=100.0, pod_down=100.0,
                                        wan=525.0))
    fab2.start_flow(0.0, 10.0, 0, 0, 99.0, "t", lambda n: None)
    (f2,) = fab2._flows.values()
    assert f2.rate == 99.0          # strictly tighter cap wins the tie


def test_insertion_order_does_not_change_rates():
    """Classes are visited in sorted-signature order, so the allocation
    cannot depend on the order flows happened to be created in."""
    links = LinkCapacities(pod_up=300.0, pod_down=300.0, wan=80.0)
    script = [(0, 1, 35.0, "x"), (0, 0, 110.0, "y"), (None, 1, 35.0, "z"),
              (1, 0, 35.0, "w"), (0, 1, 20.0, "v")]
    rates = []
    for order in (script, list(reversed(script))):
        fab, _k, _ = _bare(links)
        for src, dst, cap, kind in order:
            fab.start_flow(0.0, 50.0, src, dst, cap, kind, lambda n: None)
        rates.append(sorted((f.kind, f.rate)
                            for f in fab._flows.values()))
    assert rates[0] == rates[1]


# ------------------------------------------------- bounded completion log --
def test_log_limit_bounds_memory_and_counts_drops():
    for allocator in ALLOCATORS:
        fab, k, _ = _bare(cfg=FabricConfig(allocator=allocator,
                                           log_limit=3))
        for i in range(8):
            fab.start_flow(0.0, 10.0 + i, 0, 1, 35.0, "t", lambda n: None)
        k.run()
        s = fab.summary
        assert s.n_flows == 8
        assert len(s.completion_log) == 3
        assert s.log_dropped == 5
        assert s.by_kind["t"][0] == 8   # aggregates are never truncated


def test_log_limit_in_simulation():
    cluster = make_cluster((4, 4), links=fabric_links((4, 4),
                                                      wan_oversub=8.0))
    jobs = small_workload(cluster, seed=11, n_jobs=6)
    algo = make_algorithm("fifo", cluster)
    cfg = SimConfig(fabric=FabricConfig(log_limit=10))
    res = Simulator(cluster, algo, jobs, config=cfg, seed=11).run()
    assert res.fabric.n_flows > 10
    assert len(res.fabric.completion_log) == 10
    assert res.fabric.log_dropped == res.fabric.n_flows - 10
