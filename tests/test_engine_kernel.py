"""PR 4 tentpole: the event kernel + subsystem refactor of the simulator.

The heart of this suite is the golden-trajectory equivalence check: the
refactored engine (event heap/sequencing in ``repro.sim.engine``, elastic
churn/autoscale and durability as registered subsystems) must reproduce
the committed PR 3 trajectories **bit-identically** with the fabric off —
all five algorithms, churn and durability both off and on, speculation
included. Plus kernel units (ordering, typed registry, post-step
semantics) and the subsystem hook protocol.
"""
import pytest

from repro.sim import golden
from repro.sim.engine import EventKernel, Subsystem

GOLDEN = golden.load_golden()


@pytest.mark.parametrize("algo,variant", golden.golden_cases(),
                         ids=[golden.case_key(a, v)
                              for a, v in golden.golden_cases()])
def test_golden_trajectory_equivalence(algo, variant):
    """Fabric-off runs are bit-identical to the pre-refactor simulator:
    every task placement, start/finish instant and byte counter."""
    res = golden.run_case(algo, variant)
    assert golden.signature_hash(res) == \
        GOLDEN[golden.case_key(algo, variant)], \
        f"trajectory diverged from the PR 3 golden for {variant}/{algo}"


# ---------------------------------------------------------------- kernel --
def test_kernel_same_time_events_fire_in_push_order():
    k = EventKernel()
    seen = []
    k.register("a", lambda now, p: seen.append(("a", p)))
    k.register("b", lambda now, p: seen.append(("b", p)))
    k.push(5.0, "b", 1)
    k.push(5.0, "a", 2)
    k.push(1.0, "a", 3)
    k.run()
    assert seen == [("a", 3), ("b", 1), ("a", 2)]


def test_kernel_typed_registry():
    k = EventKernel()
    k.register("x", lambda now, p: None)
    with pytest.raises(ValueError):
        k.register("x", lambda now, p: None)   # duplicate kind
    with pytest.raises(KeyError):
        k.push(0.0, "unregistered", None)      # must register first


def test_kernel_post_step_runs_per_event():
    k = EventKernel()
    steps = []
    k.register("ev", lambda now, p: None)
    k.push(1.0, "ev", None)
    k.push(2.0, "ev", None)
    k.run(post_step=lambda now: steps.append(now))
    assert steps == [1.0, 2.0]


def test_kernel_self_stepping_kind_skips_post_step():
    k = EventKernel()
    steps = []
    k.register("quiet", lambda now, p: None, post_step=False)
    k.register("loud", lambda now, p: None)
    k.push(1.0, "quiet", None)
    k.push(2.0, "loud", None)
    k.run(post_step=lambda now: steps.append(now))
    assert steps == [2.0]


def test_kernel_handler_true_suppresses_post_step():
    """The typed replacement for the old loop's ``continue`` on stale
    events: returning True skips the post-step for that event only."""
    k = EventKernel()
    steps = []
    k.register("ev", lambda now, p: p)   # payload = skip flag
    k.push(1.0, "ev", True)
    k.push(2.0, "ev", False)
    k.run(post_step=lambda now: steps.append(now))
    assert steps == [2.0]


def test_kernel_stop_condition():
    k = EventKernel()
    seen = []
    k.register("ev", lambda now, p: seen.append(p))
    for i in range(5):
        k.push(float(i), "ev", i)
    end = k.run(stop=lambda: len(seen) == 3)
    assert seen == [0, 1, 2] and end == 2.0 and len(k) == 2


def test_kernel_call_at_runs_continuation_without_post_step():
    k = EventKernel()
    seen = []
    steps = []
    k.call_at(1.0, lambda now: seen.append(now))
    k.run(post_step=lambda now: steps.append(now))
    assert seen == [1.0] and steps == []


# ------------------------------------------------------------- subsystems --
class _Recorder(Subsystem):
    def __init__(self):
        self.events = []

    def start(self, now):
        self.events.append(("start", now))

    def on_host_added(self, hid, now):
        self.events.append(("added", hid))

    def on_host_lost(self, host, now):
        self.events.append(("lost", host.hid))

    def on_task_start(self, log, now):
        self.events.append(("task_start", log.task.tid))

    def on_task_finish(self, log, now):
        self.events.append(("task_finish", log.task.tid))

    def on_tick(self, now):
        self.events.append(("tick", now))


def _small_sim(rec, elastic=None, seed=11):
    from repro.core.joss import make_algorithm
    from repro.sim.cluster_sim import Simulator
    from repro.sim.workloads import make_cluster, small_workload
    cluster = elastic.cluster if elastic is not None else make_cluster((2, 2))
    jobs = small_workload(cluster, seed=seed, n_jobs=3)
    algo = make_algorithm("fifo", cluster)
    sim = Simulator(cluster, algo, jobs, seed=seed, elastic=elastic)
    orig = sim._setup_state
    sim._setup_state = lambda: orig() + [rec]
    return sim, jobs


def test_subsystem_hooks_fire_for_every_task():
    rec = _Recorder()
    sim, jobs = _small_sim(rec)
    res = sim.run()
    n_tasks = sum(j.m + len(j.reduce_tasks) for j in jobs)
    starts = [e for e in rec.events if e[0] == "task_start"]
    finishes = [e for e in rec.events if e[0] == "task_finish"]
    assert len(starts) == len(finishes) == n_tasks
    assert len(res.task_logs) == n_tasks
    assert rec.events[0] == ("start", 0.0)
    assert any(e[0] == "tick" for e in rec.events)


def test_subsystem_host_hooks_fire_on_churn():
    from repro.elastic import ChurnConfig, ElasticEngine, FixedFleet
    from repro.sim.workloads import make_cluster
    rec = _Recorder()
    cluster = make_cluster((3, 3))
    eng = ElasticEngine(cluster,
                        churn=ChurnConfig(seed=5, fail_rate=60.0,
                                          rejoin_delay=10.0),
                        autoscaler=FixedFleet())
    sim, _jobs = _small_sim(rec, elastic=eng)
    res = sim.run()
    lost = [e for e in rec.events if e[0] == "lost"]
    added = [e for e in rec.events if e[0] == "added"]
    assert len(lost) == res.n_host_losses > 0
    assert len(added) == res.n_host_adds > 0


def test_no_inline_event_plumbing_left():
    """Acceptance criterion: every event kind is dispatched through the
    kernel's typed registry — the simulator registers its core kinds and
    the subsystems their own; nothing is string-matched inline."""
    import inspect

    from repro.elastic import (ChurnConfig, DurabilityConfig, ElasticEngine,
                               FixedFleet)
    from repro.sim.cluster_sim import Simulator
    from repro.sim.workloads import make_cluster
    from repro.sim.workloads import small_workload
    from repro.core.joss import make_algorithm
    cluster = make_cluster((2, 2))
    jobs = small_workload(cluster, seed=3, n_jobs=2)
    eng = ElasticEngine(cluster,
                        churn=ChurnConfig(seed=4, fail_rate=1.0),
                        autoscaler=FixedFleet(),
                        durability=DurabilityConfig(rereplicate=True))
    sim = Simulator(cluster, make_algorithm("fifo", cluster), jobs,
                    seed=3, elastic=eng)
    sim.run()
    assert set(sim.kernel._handlers) >= {
        "submit", "hb", "map_done", "reduce_done", "churn", "scale",
        "rerep"}
    # the run loop itself carries no per-kind branching anymore
    src = inspect.getsource(Simulator.run)
    assert "elif kind" not in src and "heappop" not in src
