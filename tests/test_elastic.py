"""Elastic virtual-cluster invariants (PR 2).

Equivalence: with churn disabled, the elastic machinery is bit-identical
to the static simulator for all five algorithms (same harness style as
tests/test_dispatch_fastpath.py). Churn runs are deterministic per seed,
every job still completes, no task is ever assigned to a departed host,
and the re-execution/cost accounting obeys basic conservation laws. Plus
unit coverage for the mutable topology, queue patch/evacuation paths, the
lease book, the churn model, the autoscaler policies, and the Fair
scheduler's activity-keyed job order (PR 2 satellite).
"""
import random

import pytest

from repro.core.baselines import FairScheduler
from repro.core.job import Job, MapTask, ReduceTask, TaskState
from repro.core.joss import make_algorithm
from repro.core.queues import ClusterQueues
from repro.core.reference import ReferenceFair
from repro.core.topology import HostId, Locality, VirtualCluster
from repro.elastic import (ON_DEMAND, SPOT, Autoscaler,
                           BacklogThresholdScaler, ChurnConfig, ChurnModel,
                           CostCappedSpotScaler, ElasticEngine,
                           FixedFleet, FleetObservation, LeaseBook,
                           PriceSheet)
from repro.sim.cluster_sim import SimConfig, Simulator
from repro.sim.workloads import churn_scenarios, make_cluster, small_workload

from tests.test_dispatch_fastpath import random_cluster_and_jobs

ALGOS = ("joss-t", "joss-j", "fifo", "fair", "capacity")


# --------------------------------------------------------------- helpers --
def run_sim(name, seed, elastic_factory=None, n_jobs=12):
    cluster, jobs = random_cluster_and_jobs(seed, n_jobs=n_jobs)
    idx = {j.job_id: i for i, j in enumerate(jobs)}
    algo = make_algorithm(name, cluster)
    if hasattr(algo, "registry"):
        for j in jobs:
            if j.code_key in ("code0", "code1"):
                algo.registry.record(j, j.true_fp)
    elastic = elastic_factory(cluster) if elastic_factory else None
    res = Simulator(cluster, algo, jobs, seed=7, elastic=elastic).run()
    seq = [((log.task.tid[0], idx[log.task.tid[1]], *log.task.tid[2:]),
            (log.host.pod, log.host.index), log.start, log.finish)
           for log in res.task_logs]
    metrics = (res.wtt, res.int_bytes, res.pod_bytes,
               sorted((idx[k], v) for k, v in res.job_finish.items()))
    return res, metrics, seq


def mk_map(job_id, index, shard):
    return MapTask(job_id, index, shard, 128)


# ----------------------------------------------- churn-disabled identity --
@pytest.mark.parametrize("name", ALGOS)
@pytest.mark.parametrize("seed", [1, 3])
def test_churn_disabled_is_bit_identical_to_static(name, seed):
    """An attached engine with zero churn and a fixed fleet must not
    perturb the static simulator at all (its RNG is untouched)."""
    _, static_m, static_s = run_sim(name, seed)
    _, elast_m, elast_s = run_sim(
        name, seed, lambda cl: ElasticEngine(cl, autoscaler=FixedFleet()))
    assert static_m == elast_m
    assert static_s == elast_s


# -------------------------------------------------- churn determinism etc --
def flaky_engine(cluster, churn_seed=5):
    return ElasticEngine(
        cluster,
        churn=ChurnConfig(seed=churn_seed, fail_rate=2.0,
                          rejoin_delay=90.0, spot_fraction=0.25,
                          spot_preempt_rate=2.0),
        autoscaler=BacklogThresholdScaler(min_hosts=2))


@pytest.mark.parametrize("name", ALGOS)
def test_churn_runs_complete_and_are_deterministic(name):
    res_a, met_a, seq_a = run_sim(name, 2, flaky_engine)
    res_b, met_b, seq_b = run_sim(name, 2, flaky_engine)
    assert met_a == met_b and seq_a == seq_b
    assert (res_a.n_reexec, res_a.work_lost_mb, res_a.vps_hours,
            res_a.cost_dollars) == (res_b.n_reexec, res_b.work_lost_mb,
                                    res_b.vps_hours, res_b.cost_dollars)
    # every job completed despite the churn
    assert len(res_a.job_finish) == len(res_a.jobs)
    for j in res_a.jobs:
        assert j.done()


@pytest.mark.parametrize("name", ALGOS)
def test_no_task_assigned_to_departed_host(name):
    res, _, _ = run_sim(name, 4, flaky_engine)
    assert res.n_host_losses > 0, "scenario produced no churn"
    # no task may start on a host at or after its departure instant
    # (strictly before: same-instant starts would be stale slot offers);
    # and since HostIds are never reused, one removal time per host suffices
    removed = {}
    for (t, hid, _r) in res.elastic.loss_log:
        assert hid not in removed, "HostId reused after departure"
        removed[hid] = t
    for log in res.task_logs:
        if log.host in removed:
            assert log.start < removed[log.host]


def test_reexecution_accounting():
    """Churn that destroys finished map outputs forces re-runs and counts
    the lost shuffle bytes."""
    found = False
    for seed in range(1, 8):
        res, _, _ = run_sim("joss-t", seed, flaky_engine)
        if res.work_lost_mb > 0:
            assert res.n_reexec > 0
            found = True
            break
    assert found, "no seed produced lost map outputs"


def test_scenarios_cover_all_channels():
    scen = churn_scenarios()
    assert set(scen) >= {"stable", "flaky", "spot", "lease"}
    assert scen["stable"] == {}
    assert ChurnConfig(**scen["flaky"]).enabled
    assert ChurnConfig(**scen["spot"]).enabled
    assert ChurnConfig(**scen["lease"]).enabled


# ------------------------------------------------------- mutable topology --
def test_add_remove_host_replica_maintenance():
    cluster = VirtualCluster([2, 2])
    h00, h01, h10 = HostId(0, 0), HostId(0, 1), HostId(1, 0)
    cluster.place_shard("a", [h00, h10])
    cluster.place_shard("b", [h01])
    # removal drops the host's replicas
    cluster.remove_host(h00)
    assert not cluster.has_host(h00)
    assert cluster.replica_hosts("a") == frozenset({h10})
    assert cluster.replica_pods("a") == [1]
    assert cluster.locality_of("a", h01) is Locality.OFF_POD
    # last-replica loss degrades reads to off-pod, never crashes
    cluster.remove_host(h01)
    assert cluster.replica_hosts("b") == frozenset()
    assert cluster.nearest_replica("b", h10) == (None, Locality.OFF_POD)
    assert cluster.locality_of("b", h10) is Locality.OFF_POD
    # pod 0 is now empty but still listed; active_pods skips it
    assert cluster.pods[0].hosts == []
    assert cluster.active_pods() == [1]
    # indices are never reused: the next lease in pod 0 gets index 2
    h = cluster.add_host(0)
    assert h.hid == HostId(0, 2)
    assert cluster.host(h.hid) is h
    assert h.local_shards == set()
    assert cluster.active_pods() == [0, 1]


def test_greedy_cover_never_places_in_hostless_pod():
    """Policy B/C placement after churn: a job whose shards lost every
    replica must not be routed into a hostless pod (its tasks would be
    stranded forever — only a pod's own hosts serve its queues)."""
    from repro.core.policies import policy_b, policy_c
    cluster = VirtualCluster([2, 2])
    cluster.place_shard("x0", [HostId(0, 0)])
    cluster.place_shard("x1", [HostId(0, 1)])
    cluster.remove_host(HostId(0, 0))
    cluster.remove_host(HostId(0, 1))     # pod 0 dead, replicas all gone
    queues = ClusterQueues(cluster)
    job = Job(name="late", code_key="c", input_type="web",
              shard_ids=["x0", "x1"], shard_bytes=[128.0, 128.0],
              n_reducers=1)
    for policy in (policy_b, policy_c):
        plan = policy(job, cluster, queues)
        assert plan.reduce_pod == 1
        assert set(plan.map_assignment) == {1}


def test_high_churn_late_submissions_complete():
    """End-to-end: jobs submitted after heavy fleet decay (entire pods can
    die, shards lose all replicas) still complete — placement avoids
    hostless pods and reads degrade to off-pod."""
    cluster = make_cluster((3, 3))
    jobs = small_workload(cluster, seed=9, n_jobs=6)
    for i, j in enumerate(jobs):
        j.submit_time = 300.0 + 60.0 * i  # submit into the decayed fleet
    algo = make_algorithm("joss-j", cluster)
    eng = ElasticEngine(cluster, churn=ChurnConfig(
        seed=13, fail_rate=20.0, horizon=2 * 3600.0))
    res = Simulator(cluster, algo, jobs, seed=9, elastic=eng).run()
    assert res.n_host_losses >= 4
    assert len(res.job_finish) == len(jobs)


def test_least_loaded_pod_skips_hostless_pods():
    cluster = VirtualCluster([1, 2])
    queues = ClusterQueues(cluster)
    cluster.remove_host(HostId(0, 0))     # pod 0 empty but zero load
    assert queues.least_loaded_pod() == 1


# ----------------------------------------------------- queue churn hooks --
def test_taskqueue_drop_host_purges_host_index():
    cluster = VirtualCluster([2, 2])
    h00, h10 = HostId(0, 0), HostId(1, 0)
    cluster.place_shard("s", [h00, h10])
    queues = ClusterQueues(cluster)
    t = mk_map(1, 0, "s")
    queues.pods[0].mq0.append(t)
    assert queues.pods[0].mq0.peek_local(1, h00) is t
    queues.host_lost(h00)
    assert queues.pods[0].mq0.peek_local(1, h00) is None
    assert queues.pods[0].mq0.peek_local(1, h10) is t   # survivor intact


def test_mark_job_unready_reverses_ready_transition():
    queues = ClusterQueues(VirtualCluster([2, 2]))
    rq = queues.pods[0].rq0
    rq.extend([ReduceTask(1, 0), ReduceTask(1, 1)])
    queues.register_reduce_queue(1, rq)
    never = lambda t: False
    queues.mark_job_ready(1)
    assert rq.pick_ready(never, trust_marks=True) is not None
    queues.mark_job_unready(1)
    assert rq.pick_ready(never, trust_marks=True) is None
    queues.mark_job_ready(1)              # gate reopens after re-runs
    assert rq.pick_ready(never, trust_marks=True) is not None


def test_evacuate_pod_moves_work_and_ready_marks():
    cluster = VirtualCluster([2, 2])
    queues = ClusterQueues(cluster)
    ms = [mk_map(1, i, f"s{i}") for i in range(3)]
    rs = [ReduceTask(1, 0), ReduceTask(2, 0)]
    queues.pods[0].mq0.extend(ms)
    rq = queues.pods[0].new_reduce_queue()
    rq.extend(rs)
    queues.register_reduce_queue(1, rq)
    queues.register_reduce_queue(2, rq)
    queues.mark_job_ready(1)
    total_before = queues.total_pending()
    n_maps, n_reds = queues.evacuate_pod(0)
    assert (n_maps, n_reds) == (3, 2)
    assert queues.total_pending() == total_before     # moved, not created
    assert queues.pods[0].unprocessed() == 0
    assert len(queues.mq_fifo) == 3 and len(queues.rq_fifo) == 2
    never = lambda t: False
    # job 1's ready mark followed the move; job 2 stays gated
    t = queues.rq_fifo.pick_ready(never, trust_marks=True)
    assert t is rs[0]
    assert queues.rq_fifo.pick_ready(never, trust_marks=True) is None


def test_requeue_reduce_reaches_both_queues_for_marks():
    """A job whose reduces are split across its original queue and RQ_FIFO
    (churn requeue) must have gate notifications reach both."""
    cluster = VirtualCluster([2, 2])
    algo = make_algorithm("joss-t", cluster)
    queues = algo.scheduler.queues
    rq = queues.pods[1].rq0
    r_orig = ReduceTask(7, 0)
    rq.append(r_orig)
    queues.register_reduce_queue(7, rq)
    retry = ReduceTask(7, 1, attempt=1)
    algo.requeue_reduce_task(retry)
    queues.mark_job_ready(7)
    never = lambda t: False
    assert queues.rq_fifo.pick_ready(never, trust_marks=True) is retry
    assert rq.pick_ready(never, trust_marks=True) is r_orig
    queues.mark_job_unready(7)
    assert rq.pick_ready(never, trust_marks=True) is None


# ------------------------------------------------------------ lease book --
def test_lease_book_accounting():
    book = LeaseBook(PriceSheet(ondemand_per_hour=1.0, spot_per_hour=0.25))
    a, b = HostId(0, 0), HostId(0, 1)
    book.open(a, ON_DEMAND, 0.0)
    book.open(b, SPOT, 1800.0)
    book.close(a, 3600.0, "expire")
    assert book.kind_of(b) == SPOT and book.kind_of(a) is None
    # a: 1h @ $1; b: 0.5h open so far @ $0.25
    assert book.vps_hours(3600.0) == pytest.approx(1.5)
    assert book.cost(3600.0) == pytest.approx(1.0 + 0.5 * 0.25)
    book.close_all(5400.0)
    assert book.vps_hours() == pytest.approx(2.0)
    assert book.n_leases() == 2
    book2 = LeaseBook()
    book2.open(a, ON_DEMAND, 0.0)
    with pytest.raises(ValueError):
        book2.open(a, SPOT, 1.0)          # double-open


# ------------------------------------------------------------ churn model --
def test_churn_model_deterministic_and_sorted():
    cluster = VirtualCluster([3, 3])
    cfg = ChurnConfig(seed=11, fail_rate=3.0, rejoin_delay=60.0,
                      spot_fraction=0.5, spot_preempt_rate=3.0,
                      lease_term=600.0, horizon=7200.0)
    spot_a, ev_a = ChurnModel(cfg).initial_trace(cluster)
    spot_b, ev_b = ChurnModel(cfg).initial_trace(cluster)
    assert spot_a == spot_b and ev_a == ev_b
    assert ev_a == sorted(ev_a, key=lambda e: e.time)
    kinds = {e.kind for e in ev_a}
    assert "expire" in kinds              # every host gets a lease term
    assert all(0 < e.time for e in ev_a)
    # expiries are staggered over [term, 2*term)
    first_exp = [e.time for e in ev_a if e.kind == "expire"]
    assert all(600.0 <= t < 1200.0 for t in first_exp)


# ------------------------------------------------------------ autoscalers --
def obs(now=0.0, n_hosts=8, mb=0, rb=0, cost=0.0, idle=()):
    return FleetObservation(now=now, n_hosts=n_hosts, map_backlog=mb,
                            red_backlog=rb, busy_hosts=n_hosts - len(idle),
                            cost=cost, vps_hours=0.0,
                            idle_hosts=tuple(idle))


def test_fixed_fleet_never_scales():
    pol = FixedFleet()
    assert pol.interval is None
    assert pol.decide(obs(mb=1000)).empty
    assert pol.renew_lease(HostId(0, 0), ON_DEMAND, obs())


def test_backlog_scaler_out_in_and_renewal():
    pol = BacklogThresholdScaler(hi=4.0, step=3, min_hosts=4,
                                 max_hosts=10, cooldown=0.0)
    d = pol.decide(obs(n_hosts=8, mb=100))
    assert d.add == 2 and d.kind == ON_DEMAND     # capped at max_hosts
    idle = [HostId(0, i) for i in range(6)]
    d = pol.decide(obs(now=100.0, n_hosts=8, mb=0, idle=idle))
    assert d.add == 0 and len(d.remove) == 3
    # the policy trusts the observation's order (engine sorts newest
    # lease first) and returns a prefix
    assert d.remove == (HostId(0, 0), HostId(0, 1), HostId(0, 2))
    assert pol.renew_lease(HostId(0, 0), ON_DEMAND, obs(mb=5))
    assert not pol.renew_lease(HostId(0, 0), ON_DEMAND,
                               obs(n_hosts=8, mb=0))
    assert pol.renew_lease(HostId(0, 0), ON_DEMAND, obs(n_hosts=4, mb=0))


def test_backlog_scaler_cooldown():
    pol = BacklogThresholdScaler(hi=1.0, step=2, cooldown=60.0)
    assert pol.decide(obs(now=10.0, n_hosts=2, mb=50)).add == 2
    assert pol.decide(obs(now=30.0, n_hosts=4, mb=50)).empty   # cooling
    assert pol.decide(obs(now=80.0, n_hosts=4, mb=50)).add == 2


def test_cost_capped_spot_scaler_respects_budget():
    pol = CostCappedSpotScaler(budget=5.0, hi=1.0, step=2, cooldown=0.0)
    d = pol.decide(obs(n_hosts=4, mb=50, cost=1.0))
    assert d.add == 2 and d.kind == SPOT
    assert pol.decide(obs(n_hosts=4, mb=50, cost=5.0)).empty
    # over budget: spot leases lapse, on-demand renewal falls to parent
    assert not pol.renew_lease(HostId(0, 9), SPOT, obs(mb=50, cost=6.0))
    assert pol.renew_lease(HostId(0, 0), ON_DEMAND, obs(mb=50, cost=6.0))
    assert pol.renew_lease(HostId(0, 9), SPOT, obs(mb=50, cost=1.0))


def test_engine_orders_idle_hosts_newest_lease_first():
    """Scale-in victims come from the lease book's true recency order, so
    cross-pod index comparisons can't sacrifice replica-holding base
    hosts before empty surge hosts."""
    cluster = VirtualCluster([1, 3])
    eng = ElasticEngine(cluster)
    eng.startup(0.0)                       # base fleet leased at t=0
    surge = cluster.add_host(0)            # pod 0 is least populated
    eng.applied_add(surge.hid, ON_DEMAND, 500.0)
    idle = (HostId(1, 2), surge.hid, HostId(1, 0))
    o = eng.observe(600.0, map_backlog=0, red_backlog=0, busy_hosts=0,
                    idle_hosts=idle)
    assert o.idle_hosts[0] == surge.hid    # newest lease leads
    assert o.idle_hosts[1:] == (HostId(1, 0), HostId(1, 2))


def test_batch_scale_out_spreads_across_pods():
    """A multi-host scale-out batch balances pods instead of piling every
    new lease into the pod that was smallest before the batch."""
    cluster = VirtualCluster([2, 2])
    eng = ElasticEngine(cluster, autoscaler=BacklogThresholdScaler(
        hi=0.5, step=4, cooldown=0.0, max_hosts=16))
    eng.startup(0.0)
    o = eng.observe(50.0, map_backlog=40, red_backlog=0, busy_hosts=4)
    actions = eng.autoscale(o)
    assert sorted(pod for pod, _k in actions.adds) == [0, 0, 1, 1]


def test_autoscaler_instances_are_single_run():
    """A policy keeps cooldown state in absolute sim time; reusing it
    across engines would silently suppress scaling in the second run."""
    pol = BacklogThresholdScaler()
    ElasticEngine(VirtualCluster([2]), autoscaler=pol)
    with pytest.raises(ValueError):
        ElasticEngine(VirtualCluster([2]), autoscaler=pol)


def test_churn_reexecutions_not_flagged_speculative():
    """TaskLog.speculative marks straggler backups only — churn re-runs
    share the attempt counter but are not speculative."""
    res, _, _ = run_sim("joss-t", 2, flaky_engine)
    assert res.n_reexec > 0
    assert not any(l.speculative for l in res.task_logs)


def test_engine_vetoes_last_host_loss():
    cluster = VirtualCluster([1])
    eng = ElasticEngine(cluster)
    eng.startup(0.0)
    o = eng.observe(0.0, map_backlog=0, red_backlog=0, busy_hosts=0)
    from repro.elastic import ChurnEvent
    actions = eng.on_churn(ChurnEvent(1.0, "fail", 0, 0), o)
    assert actions.losses == []
    assert eng.summary.n_vetoed == 1


def test_engine_vetoes_batch_scale_in_to_zero():
    """A multi-host scale-in batch must keep at least one host even when
    the policy's min_hosts would allow dropping everything."""
    cluster = VirtualCluster([2])
    eng = ElasticEngine(cluster, autoscaler=BacklogThresholdScaler(
        min_hosts=0, cooldown=0.0))
    eng.startup(0.0)
    idle = (HostId(0, 0), HostId(0, 1))
    o = eng.observe(100.0, map_backlog=0, red_backlog=0, busy_hosts=0,
                    idle_hosts=idle)
    actions = eng.autoscale(o)
    assert len(actions.losses) == 1
    assert eng.summary.n_vetoed == 1


def test_join_follows_only_applied_failures():
    """Replacement joins pair 1:1 with failures the engine actually
    applied — a vetoed failure spawns no phantom host."""
    cluster = VirtualCluster([1])
    cfg = ChurnConfig(seed=1, fail_rate=1.0, rejoin_delay=60.0)
    eng = ElasticEngine(cluster, churn=cfg)
    eng.startup(0.0)
    from repro.elastic import ChurnEvent
    o = eng.observe(5.0, map_backlog=0, red_backlog=0, busy_hosts=0)
    actions = eng.on_churn(ChurnEvent(5.0, "fail", 0, 0), o)
    assert actions.losses == [] and actions.followups == []  # vetoed
    # with a second host, the failure applies and a join is scheduled
    cluster.add_host(0)
    actions = eng.on_churn(ChurnEvent(6.0, "fail", 0, 0), o)
    assert len(actions.losses) == 1
    assert [e.kind for e in actions.followups] == ["join"]
    assert actions.followups[0].time == pytest.approx(65.0)


# ------------------------------------- Fair activity-keyed order satellite --
def test_fair_job_order_matches_reference_sort():
    """Property test: after arbitrary interleavings of submits, task
    starts/finishes and drains, the bucketed order equals the seed's
    sorted() order."""
    rng = random.Random(123)
    cluster = VirtualCluster([2, 2])
    fast, ref = FairScheduler(cluster), ReferenceFair(cluster)
    pending, running = [], []
    for step in range(500):
        op = rng.random()
        if op < 0.2 or not (pending or running):
            m = rng.randint(1, 4)
            job = Job(name=f"f{step}", code_key="c", input_type="web",
                      shard_ids=[f"fs{step}/{b}" for b in range(m)],
                      shard_bytes=[128.0] * m, n_reducers=1,
                      submit_time=float(rng.randint(0, 50)))
            fast.submit(job)
            ref.submit(job)
            pending += job.map_tasks
        elif op < 0.6 and pending:
            t = pending.pop(rng.randrange(len(pending)))
            t.state = TaskState.RUNNING
            fast.task_started(t)
            ref.task_started(t)
            running.append(t)
        elif running:
            t = running.pop(rng.randrange(len(running)))
            t.state = TaskState.DONE
            fast.task_finished(t)
            ref.task_finished(t)
        order_fast = [j.job_id for j in fast.job_order()]
        order_ref = [j.job_id for j in ref.job_order()]
        # fast may track drained-but-running jobs the reference pruned and
        # vice versa at the margins; compare order on the common set
        common = set(order_ref) & set(order_fast)
        assert ([j for j in order_fast if j in common]
                == [j for j in order_ref if j in common])
        assert len(common) >= max(1, len(order_ref) - 1)


@pytest.mark.parametrize("seed", [5, 9])
def test_fair_pick_sequence_equivalence_under_churn(seed):
    """End-to-end: fast Fair == reference Fair trajectories still hold
    (the static equivalence tests cover this; here with a churn engine on
    the fast side against itself for determinism)."""
    res_a, met_a, seq_a = run_sim("fair", seed, flaky_engine)
    res_b, met_b, seq_b = run_sim("fair", seed, flaky_engine)
    assert met_a == met_b and seq_a == seq_b


# ------------------------------------------------------------- integration --
def test_speculative_execution_with_churn():
    """Speculative twins and churn kills share the attempt sequence: no tid
    collisions, every job completes."""
    cluster, jobs = random_cluster_and_jobs(21, n_jobs=8)
    algo = make_algorithm("joss-t", cluster)
    slow = {HostId(0, 0): 3.0}
    eng = flaky_engine(cluster)
    cfg = SimConfig(slow_hosts=slow, speculative=True)
    res = Simulator(cluster, algo, jobs, config=cfg, seed=3,
                    elastic=eng).run()
    assert len(res.job_finish) == len(jobs)


def test_churned_in_hosts_match_fleet_slot_shape():
    """Replacement/scale-out hosts inherit the cluster's construction-time
    slot configuration, so a multi-slot fleet keeps uniform capacity."""
    cluster = VirtualCluster([2, 2], map_slots=2, reduce_slots=3)
    h = cluster.add_host(0)
    assert (h.map_slots, h.reduce_slots) == (2, 3)
    assert cluster.add_host(1, map_slots=1).map_slots == 1  # explicit wins
    # end-to-end: churn on a 2-slot fleet never degrades host capacity
    cluster2 = make_cluster((4, 4), map_slots=2)
    jobs = small_workload(cluster2, seed=3, n_jobs=8)
    algo = make_algorithm("joss-t", cluster2)
    eng = ElasticEngine(cluster2, churn=ChurnConfig(
        seed=4, fail_rate=2.0, rejoin_delay=60.0))
    res = Simulator(cluster2, algo, jobs, seed=3, elastic=eng).run()
    assert res.n_host_adds > 0 or res.n_host_losses > 0
    assert len(res.job_finish) == len(jobs)
    for h in cluster2.hosts():
        assert (h.map_slots, h.reduce_slots) == (2, 1)


def test_paper_workload_under_churn_all_jobs_finish():
    cluster = make_cluster((4, 4))
    jobs = small_workload(cluster, seed=5, n_jobs=10)
    algo = make_algorithm("joss-j", cluster)
    eng = ElasticEngine(
        cluster, churn=ChurnConfig(seed=2, **churn_scenarios()["flaky"]),
        autoscaler=FixedFleet())
    res = Simulator(cluster, algo, jobs, seed=5, elastic=eng).run()
    assert len(res.job_finish) == len(jobs)
    assert res.vps_hours > 0 and res.cost_dollars > 0
