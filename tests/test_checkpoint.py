"""Checkpointing: roundtrip fidelity, atomicity, auto-resume, async, gc."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"params": {"embed": jnp.asarray(rng.randn(16, 8), jnp.float32),
                       "layers": {"w": jnp.asarray(rng.randn(2, 8, 8),
                                                   jnp.bfloat16)}},
            "opt": {"m": jnp.zeros((16, 8)), "step": jnp.int32(7)}}


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 3, t)
    restored, step = ckpt.restore(str(tmp_path), t)
    assert step == 3
    assert_tree_equal(t, restored)


def test_latest_and_resume(tmp_path):
    t = tree()
    for s in (1, 5, 9):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 9
    _, step = ckpt.restore(str(tmp_path), t)
    assert step == 9


def test_incomplete_checkpoint_ignored(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash mid-write at step 2: directory without manifest
    broken = tmp_path / "step_000000002"
    broken.mkdir()
    (broken / "shard_00000.npz").write_bytes(b"partial garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1
    _, step = ckpt.restore(str(tmp_path), t)
    assert step == 1


def test_tmp_dir_never_visible(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 4, t)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_shape_mismatch_rejected(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 1, t)
    other = tree()
    other["params"]["embed"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), other)


def test_gc_keeps_newest(tmp_path):
    t = tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, t)
    removed = ckpt.gc_old(str(tmp_path), keep=2)
    assert len(removed) == 4
    assert ckpt.latest_step(str(tmp_path)) == 5
    _, step = ckpt.restore(str(tmp_path), t)
    assert step == 5


def test_async_checkpointer(tmp_path):
    t = tree()
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ac.submit(s, t)
    ac.wait()
    assert ac.last_committed == 3
    restored, step = ckpt.restore(str(tmp_path), t)
    assert step == 3
    assert_tree_equal(t, restored)


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), tree())
