"""Multi-device semantics (8 fake CPU devices in a subprocess, because
device count locks at first jax init): shard_map collectives, the
hierarchical psum equivalence, the two-hop all_to_all, the mesh
mapreduce engine, and a tiny sharded train-step lowering."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n" +
            textwrap.dedent(code))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_hierarchical_psum_equals_flat():
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.sharding.collectives import hierarchical_psum, flat_psum
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
    h = shard_map(partial(hierarchical_psum, data_axis="data",
                          pod_axis="pod"),
                  mesh=mesh, in_specs=P("model"), out_specs=P("model"),
                  check_rep=False)(x)
    f = shard_map(partial(flat_psum, data_axis="data", pod_axis="pod"),
                  mesh=mesh, in_specs=P("model"), out_specs=P("model"),
                  check_rep=False)(x)
    np.testing.assert_allclose(np.asarray(h), np.asarray(f), rtol=1e-6)
    print("PSUM_OK")
    """)
    assert "PSUM_OK" in out


def test_two_hop_all_to_all_matches_flat():
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.sharding.collectives import two_hop_all_to_all
    mesh = jax.make_mesh((2, 4), ("pod", "model"))
    # global input: (8 ranks) x (8 dest-chunks) x payload
    x = jnp.arange(8 * 8 * 3, dtype=jnp.float32).reshape(8, 8, 3)

    def flat(xl):
        return jax.lax.all_to_all(xl[0], ("pod", "model"), split_axis=0,
                                  concat_axis=0, tiled=True)[None]

    def hier(xl):
        return two_hop_all_to_all(xl[0], pod_axis="pod",
                                  inner_axis="model")[None]

    spec = P(("pod", "model"))
    a = shard_map(flat, mesh=mesh, in_specs=spec, out_specs=spec)(x)
    b = shard_map(hier, mesh=mesh, in_specs=spec, out_specs=spec)(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    print("A2A_OK")
    """)
    assert "A2A_OK" in out


def test_mesh_mapreduce_matches_local():
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.mapreduce import JOBS, corpus, local_mapreduce, mesh_mapreduce
    mesh = jax.make_mesh((8,), ("data",))
    spec = JOBS["WC"]
    toks, lens = [], []
    for s in range(8):
        t, l = corpus("non-web", 512, seed=s)
        toks.append(t); lens.append(l)
    toks = jnp.asarray(np.stack(toks)); lens = jnp.asarray(np.stack(lens))
    uk, uv, n, dropped = mesh_mapreduce(spec, toks, lens, mesh,
                                        shuffle_axes=("data",))
    assert int(dropped.sum()) == 0
    got = {}
    for d in range(8):
        for kk, vv in zip(np.asarray(uk[d]), np.asarray(uv[d])):
            if kk != 0xFFFFFFFF:
                got[int(kk)] = got.get(int(kk), 0) + int(vv)
    import collections
    expect = collections.Counter()
    for row in np.asarray(toks):
        expect.update(int(x) for x in row if x >= 0)
    assert got == dict(expect), (len(got), len(expect))
    print("MR_OK")
    """)
    assert "MR_OK" in out


def test_tiny_sharded_train_step_executes():
    """Not just lowering: run a real sharded train step on 8 devices."""
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.common import axes_tree, shape_tree
    from repro.sharding import DEFAULT_RULES, tree_shardings, use_rules
    from repro.train import TrainConfig, adamw_init, make_train_step
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen3-4b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    specs = model.param_specs()
    psh = tree_shardings(mesh, DEFAULT_RULES, axes_tree(specs),
                         shape_tree(specs))
    params = jax.device_put(params, psh)
    opt = adamw_init(params)
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, (8, 32)), jnp.int32)}
    step = make_train_step(model, TrainConfig(n_micro=2))
    with use_rules(mesh, DEFAULT_RULES):
        fn = jax.jit(step, in_shardings=(psh, None, None))
        p2, o2, m = fn(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    print("TRAIN_OK", float(m["loss"]))
    """)
    assert "TRAIN_OK" in out


def test_moe_ep_matches_dense_dispatch():
    """Expert-parallel shard_map dispatch == sort-based dense dispatch
    (high capacity factor -> no drops on either path)."""
    out = run_sub("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.moe import moe_ffn
    from repro.models.moe_ep import moe_ffn_ep
    from repro.models.common import init_tree, ParamSpec
    from repro.sharding import DEFAULT_RULES, use_rules
    from repro.models.moe import moe_specs

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("dbrx-132b").smoke().scaled(
        n_experts=8, moe_topk=2, capacity_factor=8.0)
    specs = moe_specs(cfg, 1)
    p = init_tree(jax.random.PRNGKey(0), specs)
    p = {k: v[0] for k, v in p.items()}   # drop the layer dim
    x = jnp.asarray(np.random.RandomState(0).randn(2, 32, cfg.d_model),
                    jnp.float32)
    y_dense, aux_d = moe_ffn(cfg, p, x)
    with use_rules(mesh, DEFAULT_RULES):
        y_ep, aux_e = jax.jit(lambda pp, xx: moe_ffn_ep(cfg, pp, xx))(p, x)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                               atol=2e-4, rtol=1e-3)
    # aux: EP uses the per-device Switch estimator (standard for EP);
    # same ballpark as the global estimate, not bit-equal
    assert abs(float(aux_d) - float(aux_e)) < 0.5
    print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out
