"""Training substrate: optimizer math, microbatch-accumulation exactness,
gradient compression error feedback, and a real overfit run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.train import (OptConfig, TrainConfig, adamw_init, adamw_update,
                         init_train_state, lr_schedule, make_train_step)
from repro.train.compress import compress_decompress, quantize_int8


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 55, 100, 200)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)
    assert lrs[5] == pytest.approx(0.1)


def test_adamw_moves_params_toward_gradient():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    state = adamw_init(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    new_p, state, metrics = adamw_update(cfg, grads, state, params)
    assert float(new_p["w"][0, 0]) < 1.0
    assert int(state["step"]) == 1
    assert metrics["grad_norm"] == pytest.approx(4.0)


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("qwen3-4b").smoke()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 4, 32
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, (B, S)), jnp.int32)}
    opt = OptConfig(lr=1e-2, warmup_steps=0, grad_clip=0.0,
                    weight_decay=0.0)
    s1 = make_train_step(model, TrainConfig(opt=opt, n_micro=1))
    s2 = make_train_step(model, TrainConfig(opt=opt, n_micro=2))
    o1 = adamw_init(params)
    o2 = adamw_init(params)
    p1, o1, m1 = jax.jit(s1)(params, o1, batch)
    p2, o2, m2 = jax.jit(s2)(params, o2, batch)
    # means of per-microbatch losses == full-batch loss (equal-size masks)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-5, rtol=2e-4)


def test_overfit_tiny_model():
    """A few hundred gradient steps on one batch must crush the loss —
    the end-to-end 'this actually trains' check."""
    cfg = get_config("granite-3-2b").smoke().scaled(vocab=64, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(1).randint(0, 64, (2, 32)), jnp.int32)}
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5,
                                     total_steps=200, weight_decay=0.0))
    step = jax.jit(make_train_step(model, tcfg))
    opt_state = adamw_init(params)
    first = None
    for i in range(60):
        params, opt_state, metrics = step(params, opt_state, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first * 0.5, (first, last)


def test_int8_quantize_roundtrip_small_error():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(256) * 0.01, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(quantize_int8(x)[0].astype(jnp.float32) * s - x)
    assert float(err.max()) <= float(s) / 2 + 1e-9


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated applied gradient converges to
    the accumulated true gradient (residual stays bounded)."""
    rng = np.random.RandomState(0)
    g_true = {"w": jnp.asarray(rng.randn(64) * 1e-3, jnp.float32)}
    ef = None
    applied = jnp.zeros(64)
    for t in range(50):
        deq, ef = compress_decompress(g_true, ef)
        applied += deq["w"]
    total_true = g_true["w"] * 50
    resid = float(jnp.abs(applied - total_true).max())
    # residual bounded by one quantization step, NOT growing with t
    assert resid <= float(jnp.abs(g_true["w"]).max()) * 2


def test_train_state_with_compression_runs():
    cfg = get_config("qwen3-4b").smoke()
    model = build_model(cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0),
                       compress_grads=True)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, (2, 16)), jnp.int32)}
    step = jax.jit(make_train_step(model, tcfg))
    params, opt_state, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert "ef" in opt_state
