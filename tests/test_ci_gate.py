"""The bench-regression CI gate (PR 3 satellite): the gate passes when the
fresh measurement matches the committed trajectory and demonstrably fails
on an injected 2x slowdown — without paying for real wall-clock
measurements in the test (the measurement functions are stubbed to echo
the stored trajectory; ``scripts/ci.sh`` runs the real thing)."""
import importlib.util
import json
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_gate():
    path = os.path.join(_ROOT, "scripts", "check_bench_regression.py")
    spec = importlib.util.spec_from_file_location("check_bench_regression",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def gate():
    return _load_gate()


@pytest.fixture(scope="module")
def stored():
    with open(os.path.join(_ROOT, "BENCH_dispatch.json")) as f:
        return json.load(f)


def _stored_assign_us(stored):
    gate = _load_gate()
    return {gate._key(e): 1e6 / e["new_tasks_per_s"]
            for e in gate.gated_assign_entries(stored)}


def test_trajectory_covers_the_gated_points(stored):
    """The committed trajectory must contain the acceptance points the
    gate asserts on (4096- and 8192-host)."""
    hosts = {e["hosts"] for e in stored["assign"]}
    assert {4096, 8192} <= hosts
    assert stored["events"], "no event-rate trajectory committed"


def test_compare_passes_on_identical_measurement(gate, stored):
    fresh = _stored_assign_us(stored)
    ev = max(stored["events"], key=lambda e: e["hosts"])
    assert gate.compare(stored, fresh, ev["new_events_per_s"], 0.25) == []


def test_compare_tolerates_sub_threshold_noise(gate, stored):
    fresh = {k: v * 1.2 for k, v in _stored_assign_us(stored).items()}
    ev = max(stored["events"], key=lambda e: e["hosts"])
    assert gate.compare(stored, fresh,
                        ev["new_events_per_s"] / 1.2, 0.25) == []


def test_compare_fails_on_2x_slowdown(gate, stored):
    fresh = {k: v * 2.0 for k, v in _stored_assign_us(stored).items()}
    ev = max(stored["events"], key=lambda e: e["hosts"])
    failures = gate.compare(stored, fresh,
                            ev["new_events_per_s"] / 2.0, 0.25)
    # every gated assign point plus the event point trips
    assert len(failures) == len(fresh) + 1
    assert all("regression" in f for f in failures)


def _stub_measurements(gate, monkeypatch):
    """Echo the stored trajectories instead of measuring (the test
    shouldn't pay wall-clock; ``scripts/ci.sh`` runs the real thing)."""
    monkeypatch.setattr(
        gate, "_fresh_assign_us",
        lambda entry: 1e6 / entry["new_tasks_per_s"])
    monkeypatch.setattr(
        gate, "_fresh_events_per_s",
        lambda entry, reps=2: entry["new_events_per_s"])
    monkeypatch.setattr(gate, "_fresh_wtt", lambda point: point["wtt"])
    monkeypatch.setattr(
        gate, "_fresh_fabric_events_per_s",
        lambda point, reps=2: point["fast_events_per_s"])

    def _echo_migration(stored_mig, perturb=0.0):
        fresh = {a: dict(v, lost=v["lost"] + perturb,
                         base_lost=v["base_lost"] + perturb)
                 for a, v in stored_mig["algos"].items()}
        sig = stored_mig["signature"]
        fresh["signature"] = sig + "!" if perturb else sig
        return fresh
    monkeypatch.setattr(gate, "_fresh_migration", _echo_migration)

    def _echo_chaos(stored_chaos, perturb=0.0):
        fresh = {a: dict(v, wtt=v["wtt"] + perturb)
                 for a, v in stored_chaos["algos"].items()}
        for key in ("chaos_signature", "response_signature"):
            sig = stored_chaos[key]
            fresh[key] = sig + "!" if perturb else sig
        return fresh
    monkeypatch.setattr(gate, "_fresh_chaos", _echo_chaos)

    def _echo_obs(stored_obs, perturb=False):
        p = stored_obs["probe"]
        return {"sha256": p["sha256"] + "!" if perturb else p["sha256"],
                "n_events": p["n_events"]}
    monkeypatch.setattr(gate, "_fresh_obs_probe", _echo_obs)

    def _echo_sweep():
        with open(os.path.join(_ROOT, "BENCH_sweep.json")) as f:
            g = json.load(f)["gate"]
        return {"n_seeds": g["n_seeds"], "speedup": g["speedup"],
                "warm_cells_per_s": g["warm_cells_per_s"],
                "serial_cells_per_s": g["serial_cells_per_s"]}
    monkeypatch.setattr(gate, "_fresh_sweep", _echo_sweep)

    def _echo_lockstep(perturb=1.0):
        with open(os.path.join(_ROOT, "BENCH_sweep.json")) as f:
            lk = json.load(f)["lockstep"]
        return {"n_seeds": lk["n_seeds"], "n_cells": lk["n_cells"],
                "identical": True, "used_jax": True,
                "fill_speedup": lk["fill_speedup"] / perturb}
    monkeypatch.setattr(gate, "_fresh_lockstep", _echo_lockstep)

    def _echo_claims(perturb=0.0):
        # echo the committed claim rows; the perturbation shifts every
        # WTT-derived row exactly like the real _fresh_claims (gap rows
        # scale too — the *good* direction, so only wtt rows may trip)
        def shift(row):
            if perturb and row["metric"] in ("wtt", "wtt_gap"):
                row = {**row, "mean": row["mean"] * (1 + perturb),
                       "ci_lo": row["ci_lo"] * (1 + perturb),
                       "ci_hi": row["ci_hi"] * (1 + perturb)}
            return row
        with open(os.path.join(_ROOT, "BENCH_fabric.json")) as f:
            fab = json.load(f)["claims"]
        with open(os.path.join(_ROOT, "BENCH_elastic.json")) as f:
            ela = json.load(f)["claims"]
        return {"fabric": [shift(r)
                           for r in fab["rows"] + fab["gaps"]],
                "elastic": [shift(r) for r in ela["rows"]]}
    monkeypatch.setattr(gate, "_fresh_claims", _echo_claims)


def test_main_trips_on_injected_slowdown(gate, stored, monkeypatch):
    """End-to-end through main(): stubbed measurements echo the stored
    trajectory, so --slowdown 1 passes and --slowdown 2 must exit 1."""
    _stub_measurements(gate, monkeypatch)
    assert gate.main([]) == 0
    assert gate.main(["--slowdown", "2.0"]) == 1


def test_main_fails_cleanly_without_trajectory(gate, tmp_path):
    assert gate.main(["--json", str(tmp_path / "missing.json")]) == 1


# ------------------------------------------- elastic-WTT gate (PR 4) --
@pytest.fixture(scope="module")
def stored_elastic():
    with open(os.path.join(_ROOT, "BENCH_elastic.json")) as f:
        return json.load(f)


def test_elastic_trajectory_covers_two_scenario_points(stored_elastic):
    """ROADMAP item: gate elastic-scenario WTT at two (scenario, fleet)
    points once BENCH history exists."""
    keys = {(p["scenario"], tuple(p["fleet"]))
            for p in stored_elastic["points"]}
    assert len(keys) >= 2
    assert all(p["wtt"] > 0 for p in stored_elastic["points"])


def test_compare_elastic_passes_on_identical_wtt(gate, stored_elastic):
    fresh = {(p["scenario"], p["algo"]): p["wtt"]
             for p in stored_elastic["points"]}
    assert gate.compare_elastic(stored_elastic, fresh, 0.001) == []


def test_compare_elastic_fails_on_behaviour_drift(gate, stored_elastic):
    fresh = {(p["scenario"], p["algo"]): p["wtt"] * 1.01
             for p in stored_elastic["points"]}
    failures = gate.compare_elastic(stored_elastic, fresh, 0.001)
    assert len(failures) == len(stored_elastic["points"])
    assert all("behaviour changed" in f for f in failures)


def test_main_trips_on_wtt_perturbation(gate, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--wtt-perturb", "1.01"]) == 1


def test_main_fails_cleanly_without_elastic_trajectory(gate, tmp_path,
                                                       monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--elastic-json",
                      str(tmp_path / "missing.json")]) == 1


def test_elastic_gate_reproduces_stored_wtt_live(gate, stored_elastic):
    """One real re-simulation (not stubbed): the committed WTT must be
    exactly reproducible — the simulation is deterministic per seed."""
    point = stored_elastic["points"][0]
    assert gate._fresh_wtt(point) == pytest.approx(point["wtt"],
                                                   rel=1e-12)


# ------------------------------------------------ fabric gate (PR 5) --
@pytest.fixture(scope="module")
def stored_fabric():
    with open(os.path.join(_ROOT, "BENCH_fabric.json")) as f:
        return json.load(f)


def test_fabric_trajectory_covers_the_gate_point(stored_fabric):
    g = stored_fabric["gate"]
    assert g["hosts"] == 4096 and g["fast_events_per_s"] > 0
    assert g["speedup"] >= 5.0, \
        "committed fabric gate point below the 5x acceptance envelope"
    assert {e["hosts"] for e in stored_fabric["e2e"]} >= {1024, 4096}


def test_compare_fabric_passes_on_identical_measurement(gate,
                                                        stored_fabric):
    fresh = stored_fabric["gate"]["fast_events_per_s"]
    assert gate.compare_fabric(stored_fabric, fresh, 0.25) == []


def test_compare_fabric_fails_on_2x_slowdown(gate, stored_fabric):
    fresh = stored_fabric["gate"]["fast_events_per_s"] / 2.0
    failures = gate.compare_fabric(stored_fabric, fresh, 0.25)
    assert len(failures) == 1 and "regression" in failures[0]


def test_compare_fabric_fails_on_sub_envelope_speedup(gate,
                                                      stored_fabric):
    doctored = {"gate": dict(stored_fabric["gate"], speedup=4.2)}
    failures = gate.compare_fabric(
        doctored, doctored["gate"]["fast_events_per_s"], 0.25)
    assert len(failures) == 1 and "acceptance envelope" in failures[0]


def test_main_trips_on_fabric_perturbation(gate, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--fabric-perturb", "2.0"]) == 1


def test_main_fails_cleanly_without_fabric_trajectory(gate, tmp_path,
                                                      monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--fabric-json",
                      str(tmp_path / "missing.json")]) == 1


# --------------------------------------------- migration gate (PR 6) --
def _fresh_from_stored(m):
    fresh = {a: dict(v) for a, v in m["algos"].items()}
    fresh["signature"] = m["signature"]
    return fresh


def test_migration_row_committed(stored_elastic):
    """The committed gate row must cover all five algorithms with a
    baseline that actually loses work (else the gate asserts nothing)."""
    m = stored_elastic["migration"]
    assert set(m["algos"]) == {"joss-t", "joss-j", "fifo", "fair",
                               "capacity"}
    assert all(v["base_lost"] > 0 for v in m["algos"].values())
    assert sum(v["n_migrated"] for v in m["algos"].values()) > 0
    assert m["signature"] and m["probe"]["notice"] > 0


def test_compare_migration_passes_on_identical_row(gate, stored_elastic):
    m = stored_elastic["migration"]
    assert gate.compare_migration(m, _fresh_from_stored(m)) == []


def test_compare_migration_fails_on_loss_drift(gate, stored_elastic):
    m = stored_elastic["migration"]
    fresh = _fresh_from_stored(m)
    fresh["joss-t"]["lost"] = 0.5 * fresh["joss-t"]["base_lost"]
    failures = gate.compare_migration(m, fresh)
    assert any("> 5%" in f for f in failures)          # envelope broken
    assert any("drifted" in f for f in failures)       # determinism pin


def test_compare_migration_fails_on_signature_drift(gate,
                                                    stored_elastic):
    m = stored_elastic["migration"]
    fresh = _fresh_from_stored(m)
    fresh["signature"] = "0000decafbad"
    failures = gate.compare_migration(m, fresh)
    assert len(failures) == 1 and "signature drifted" in failures[0]


def test_compare_migration_fails_on_dead_restore_path(gate,
                                                      stored_elastic):
    m = stored_elastic["migration"]
    fresh = _fresh_from_stored(m)
    for a in fresh:
        if a != "signature":
            fresh[a]["n_migrated"] = 0
    failures = gate.compare_migration(m, fresh)
    assert any("restore path" in f for f in failures)


def test_main_trips_on_migration_perturbation(gate, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--migration-perturb", "64.0"]) == 1


def test_main_fails_cleanly_without_migration_row(gate, stored_elastic,
                                                  tmp_path, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    crippled = {k: v for k, v in stored_elastic.items()
                if k != "migration"}
    p = tmp_path / "elastic.json"
    p.write_text(json.dumps(crippled))
    assert gate.main(["--elastic-json", str(p)]) == 1


def test_migration_gate_matches_stored_row_live(gate, stored_elastic):
    """One real re-simulation (not stubbed): the committed row must be
    exactly reproducible — the probe is deterministic per seed."""
    m = stored_elastic["migration"]
    assert gate.compare_migration(m, gate._fresh_migration(m)) == []


# ------------------------------------------------- chaos gate (PR 10) --
@pytest.fixture(scope="module")
def stored_chaos():
    with open(os.path.join(_ROOT, "BENCH_chaos.json")) as f:
        return json.load(f)


def _chaos_fresh_from_stored(c):
    fresh = {a: dict(v) for a, v in c["algos"].items()}
    fresh["chaos_signature"] = c["chaos_signature"]
    fresh["response_signature"] = c["response_signature"]
    return fresh


def test_chaos_row_committed(stored_chaos):
    """The committed gate row must cover all five algorithms, hold the
    detection-beats-off envelope, and actually exercise the response
    loop (else the gate asserts nothing)."""
    c = stored_chaos["algos"]
    assert set(c) == {"joss-t", "joss-j", "fifo", "fair", "capacity"}
    for v in c.values():
        assert v["wtt"] < v["off_wtt"]
        assert v["reexec"] < v["off_reexec"]
    assert sum(v["n_timeouts"] for v in c.values()) > 0
    assert sum(v["n_quarantined"] for v in c.values()) > 0
    assert stored_chaos["chaos_signature"]
    assert stored_chaos["response_signature"]
    assert stored_chaos["gate"]["campaign"]["n_outages"] > 0


def test_compare_chaos_passes_on_identical_row(gate, stored_chaos):
    assert gate.compare_chaos(
        stored_chaos, _chaos_fresh_from_stored(stored_chaos)) == []


def test_compare_chaos_fails_on_broken_envelope(gate, stored_chaos):
    fresh = _chaos_fresh_from_stored(stored_chaos)
    fresh["joss-t"]["wtt"] = fresh["joss-t"]["off_wtt"] + 1.0
    failures = gate.compare_chaos(stored_chaos, fresh)
    assert any("did not cut WTT" in f for f in failures)   # envelope
    assert any("drifted" in f for f in failures)           # determinism


def test_compare_chaos_fails_on_signature_drift(gate, stored_chaos):
    fresh = _chaos_fresh_from_stored(stored_chaos)
    fresh["response_signature"] = "0000decafbad"
    failures = gate.compare_chaos(stored_chaos, fresh)
    assert len(failures) == 1 and "signature drifted" in failures[0]


def test_compare_chaos_fails_on_dead_response_loop(gate, stored_chaos):
    fresh = _chaos_fresh_from_stored(stored_chaos)
    for a, v in fresh.items():
        if isinstance(v, dict):
            v["n_timeouts"] = v["n_quarantined"] = 0
    failures = gate.compare_chaos(stored_chaos, fresh)
    assert any("response loop" in f for f in failures)


def test_main_trips_on_chaos_perturbation(gate, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--chaos-perturb", "64.0"]) == 1


def test_main_fails_cleanly_without_chaos_trajectory(gate, tmp_path,
                                                     monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--chaos-json",
                      str(tmp_path / "missing.json")]) == 1


def test_chaos_gate_matches_stored_row_live(gate, stored_chaos):
    """One real re-simulation (not stubbed): the committed row must be
    exactly reproducible — the probe is deterministic per seed."""
    assert gate.compare_chaos(stored_chaos,
                              gate._fresh_chaos(stored_chaos)) == []


# --------------------------------------------------- obs gate (PR 7) --
@pytest.fixture(scope="module")
def stored_obs():
    with open(os.path.join(_ROOT, "BENCH_obs.json")) as f:
        return json.load(f)


def _obs_fresh_from_stored(o):
    return {"sha256": o["probe"]["sha256"],
            "n_events": o["probe"]["n_events"]}


def test_obs_trajectory_covers_the_gate_point(stored_obs):
    g = stored_obs["gate"]
    assert g["hosts"] == 4096 and g["off_events_per_s"] > 0
    assert g["ratio"] >= 0.90, \
        "committed telemetry gate point below the 90% overhead envelope"
    p = stored_obs["probe"]
    assert len(p["sha256"]) == 64 and p["n_events"] > 0


def test_compare_obs_passes_on_identical_probe(gate, stored_obs):
    assert gate.compare_obs(stored_obs,
                            _obs_fresh_from_stored(stored_obs)) == []


def test_compare_obs_fails_on_sha_drift(gate, stored_obs):
    fresh = _obs_fresh_from_stored(stored_obs)
    fresh["sha256"] = "0000decafbad"
    failures = gate.compare_obs(stored_obs, fresh)
    assert len(failures) == 1 and "sha256 drifted" in failures[0]


def test_compare_obs_fails_on_event_count_drift(gate, stored_obs):
    fresh = _obs_fresh_from_stored(stored_obs)
    fresh["n_events"] += 1
    failures = gate.compare_obs(stored_obs, fresh)
    assert len(failures) == 1 and "event count drifted" in failures[0]


def test_compare_obs_fails_on_sub_envelope_ratio(gate, stored_obs):
    doctored = dict(stored_obs, gate=dict(stored_obs["gate"], ratio=0.7))
    failures = gate.compare_obs(doctored,
                                _obs_fresh_from_stored(stored_obs))
    assert len(failures) == 1 and "acceptance envelope" in failures[0]


def test_main_trips_on_obs_perturbation(gate, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--obs-perturb"]) == 1


def test_main_fails_cleanly_without_obs_trajectory(gate, tmp_path,
                                                   monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--obs-json", str(tmp_path / "missing.json")]) == 1


def test_obs_gate_matches_stored_probe_live(gate, stored_obs):
    """One real re-simulation (not stubbed): the committed trace probe
    must be exactly reproducible — the trace is deterministic per seed."""
    assert gate.compare_obs(stored_obs,
                            gate._fresh_obs_probe(stored_obs)) == []


# --------------------------------------- statistical sweep gates (PR 8) --
@pytest.fixture(scope="module")
def stored_sweep():
    with open(os.path.join(_ROOT, "BENCH_sweep.json")) as f:
        return json.load(f)


def test_sweep_trajectory_holds_the_envelope(stored_sweep):
    g = stored_sweep["gate"]
    assert g["n_seeds"] >= 32, \
        "committed sweep gate measured below 32 seeds"
    assert g["speedup"] >= 20.0, \
        "committed sweep gate below the 20x warm-store envelope"
    assert stored_sweep["determinism"]["aggregate_sha256"]
    assert stored_sweep["matrix"]["n_cells"] >= 32 * 5 * 3


def test_committed_claims_carry_32_seeds_with_cis(stored_fabric,
                                                  stored_elastic):
    """The acceptance criterion: every committed BENCH claim row has
    n >= 32 replicas and a well-formed bootstrap CI around its mean."""
    for stored in (stored_fabric, stored_elastic):
        claims = stored["claims"]
        assert claims["n_seeds"] >= 32
        rows = claims["rows"] + claims.get("gaps", [])
        assert rows, "empty claims block"
        for r in rows:
            assert r["n"] >= 32
            assert r["ci_lo"] <= r["mean"] <= r["ci_hi"]


def test_compare_sweep_passes_on_committed_gate(gate, stored_sweep):
    assert gate.compare_sweep(stored_sweep,
                              dict(stored_sweep["gate"])) == []


def test_compare_sweep_fails_below_stored_envelope(gate, stored_sweep):
    doctored = {"gate": dict(stored_sweep["gate"], speedup=10.0)}
    failures = gate.compare_sweep(doctored, dict(stored_sweep["gate"]))
    assert len(failures) == 1 and "acceptance envelope" in failures[0]


def test_compare_sweep_fails_on_fresh_cache_rot(gate, stored_sweep):
    fresh = dict(stored_sweep["gate"], speedup=3.0)
    failures = gate.compare_sweep(stored_sweep, fresh)
    assert len(failures) == 1 and "no longer serving" in failures[0]


def _fabric_claim_rows(stored_fabric):
    c = stored_fabric["claims"]
    return c["rows"] + c["gaps"]


def test_compare_sweep_claims_passes_on_identical_rows(gate,
                                                       stored_fabric):
    assert gate.compare_sweep_claims(stored_fabric["claims"],
                                     _fabric_claim_rows(stored_fabric),
                                     "fabric") == []


def test_compare_sweep_claims_fires_on_disjoint_ci(gate, stored_fabric):
    fresh = [({**r, "ci_lo": r["ci_hi"] * 2 + 1.0,
               "ci_hi": r["ci_hi"] * 2 + 2.0}
              if r["metric"] == "wtt" else r)
             for r in _fabric_claim_rows(stored_fabric)]
    failures = gate.compare_sweep_claims(stored_fabric["claims"], fresh,
                                         "fabric")
    n_wtt = sum(1 for r in stored_fabric["claims"]["rows"]
                if r["metric"] == "wtt")
    assert len(failures) == n_wtt
    assert all("bad direction" in f for f in failures)


def test_compare_sweep_claims_good_direction_never_trips(gate,
                                                         stored_fabric):
    """A fresh CI disjoint *below* the stored one (faster WTT) passes;
    a gap CI disjoint *above* (bigger JoSS win) passes too."""
    fresh = []
    for r in _fabric_claim_rows(stored_fabric):
        if r["metric"] == "wtt":
            fresh.append({**r, "ci_lo": r["ci_lo"] * 0.25,
                          "ci_hi": r["ci_lo"] * 0.5})
        elif r["metric"] == "wtt_gap":
            fresh.append({**r, "ci_lo": r["ci_hi"] * 2,
                          "ci_hi": r["ci_hi"] * 3})
        else:
            fresh.append(r)
    assert gate.compare_sweep_claims(stored_fabric["claims"], fresh,
                                     "fabric") == []


def test_compare_sweep_claims_fails_on_missing_counterpart(
        gate, stored_fabric):
    fresh = [r for r in _fabric_claim_rows(stored_fabric)
             if r["metric"] != "wtt_gap"]
    failures = gate.compare_sweep_claims(stored_fabric["claims"], fresh,
                                         "fabric")
    n_gaps = len(stored_fabric["claims"]["gaps"])
    assert len(failures) == n_gaps
    assert all("no fresh counterpart" in f for f in failures)


def test_compare_sweep_claims_fails_on_thin_replicas(gate,
                                                     stored_fabric):
    row = dict(stored_fabric["claims"]["rows"][0], n=8)
    claims = {"n_seeds": 8, "rows": [row], "gaps": []}
    failures = gate.compare_sweep_claims(claims, [row], "fabric")
    assert any("n_seeds=8" in f for f in failures)
    assert any("8 replicas" in f for f in failures)


# -------------------------------------------- lockstep gate (PR 9) --
def test_lockstep_block_committed(stored_sweep):
    """The acceptance criterion: the committed lockstep block carries
    the full-seed gate point and holds the 3x fill-path envelope."""
    lk = stored_sweep["lockstep"]
    assert lk["n_seeds"] >= 32
    assert lk["n_cells"] == 5 * 3 * lk["n_seeds"]
    assert lk["hosts_per_pod"] == [8] * 8 and lk["n_jobs"] == 24
    assert lk["fill_speedup"] >= 3.0, \
        "committed lockstep gate below the 3x fill-path envelope"
    assert lk["scalar_fill_s"] > lk["lockstep_fill_s"] > 0
    # deferred coalescing: the lockstep path delivers strictly fewer
    # problems than the inline path solves
    assert 0 < lk["problems"] < lk["scalar_fills"]
    assert lk["batches"] > 0 and len(lk["aggregate_sha256"]) == 64


def _lockstep_fresh_from_stored(lk):
    return {"n_seeds": lk["n_seeds"], "n_cells": lk["n_cells"],
            "identical": True, "used_jax": True,
            "fill_speedup": lk["fill_speedup"]}


def test_compare_lockstep_passes_on_committed_block(gate, stored_sweep):
    lk = stored_sweep["lockstep"]
    assert gate.compare_lockstep(lk,
                                 _lockstep_fresh_from_stored(lk)) == []


def test_compare_lockstep_fails_below_stored_envelope(gate,
                                                      stored_sweep):
    lk = dict(stored_sweep["lockstep"], fill_speedup=2.0)
    failures = gate.compare_lockstep(lk,
                                     _lockstep_fresh_from_stored(lk))
    assert any("acceptance envelope" in f for f in failures)


def test_compare_lockstep_fails_on_thin_seeds(gate, stored_sweep):
    lk = dict(stored_sweep["lockstep"], n_seeds=8)
    failures = gate.compare_lockstep(lk,
                                     _lockstep_fresh_from_stored(lk))
    assert any("n_seeds=8" in f for f in failures)


def test_compare_lockstep_fails_on_identity_break(gate, stored_sweep):
    lk = stored_sweep["lockstep"]
    fresh = dict(_lockstep_fresh_from_stored(lk), identical=False)
    failures = gate.compare_lockstep(lk, fresh)
    assert len(failures) == 1 and "behaviour" in failures[0]


def test_compare_lockstep_smoke_floor(gate, stored_sweep):
    """Fresh reduced-seed speedups are noisy: anything above half the
    envelope passes; below it trips; without jax the wall-clock check
    is skipped entirely (bit-identity of the scalar path still gates)."""
    lk = stored_sweep["lockstep"]
    ok = dict(_lockstep_fresh_from_stored(lk), fill_speedup=1.6)
    assert gate.compare_lockstep(lk, ok) == []
    slow = dict(ok, fill_speedup=1.0)
    failures = gate.compare_lockstep(lk, slow)
    assert len(failures) == 1 and "smoke floor" in failures[0]
    nojax = dict(slow, used_jax=False)
    assert gate.compare_lockstep(lk, nojax) == []


def test_main_trips_on_lockstep_perturbation(gate, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--lockstep-perturb", "4.0"]) == 1


def test_main_fails_without_lockstep_block(gate, stored_sweep,
                                           tmp_path, monkeypatch):
    _stub_measurements(gate, monkeypatch)
    crippled = {k: v for k, v in stored_sweep.items()
                if k != "lockstep"}
    p = tmp_path / "sweep.json"
    p.write_text(json.dumps(crippled))
    assert gate.main(["--sweep-json", str(p)]) == 1


def test_main_trips_on_ci_perturbation(gate, monkeypatch):
    """End-to-end self-test: an injected mean shift far beyond the CI
    width must trip the statistical gate; noise within the CI must
    pass."""
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--ci-perturb", "0.5"]) == 1
    assert gate.main(["--ci-perturb", "0.002"]) == 0


def test_main_fails_cleanly_without_sweep_trajectory(gate, tmp_path,
                                                     monkeypatch):
    _stub_measurements(gate, monkeypatch)
    assert gate.main(["--sweep-json",
                      str(tmp_path / "missing.json")]) == 1


def test_main_fails_without_claims_block(gate, stored_fabric, tmp_path,
                                         monkeypatch):
    _stub_measurements(gate, monkeypatch)
    crippled = {k: v for k, v in stored_fabric.items() if k != "claims"}
    p = tmp_path / "fabric.json"
    p.write_text(json.dumps(crippled))
    assert gate.main(["--fabric-json", str(p)]) == 1


def test_sweep_gate_reproduces_stored_claims_live(gate, stored_fabric):
    """One real reduced-seed sweep (not stubbed): the fresh CI rows
    must overlap the committed ones — the cells are deterministic and
    the committed means came from the same matrix."""
    fresh = gate._fresh_claims()
    assert gate.compare_sweep_claims(stored_fabric["claims"],
                                     fresh["fabric"], "fabric") == []
