"""Cross-subsystem integration: pipeline->train->checkpoint->resume,
FP-noise robustness of classification, elastic replan after failure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.topology import HostId, VirtualCluster
from repro.data import JossDataPipeline, TokenStore
from repro.models import build_model
from repro.runtime import HealthTracker, plan_elastic_remesh
from repro.sim.cluster_sim import SimConfig
from repro.sim.experiment import run_one
from repro.train import (OptConfig, TrainConfig, adamw_init,
                         make_train_step)
from repro.train import checkpoint as ckpt


def test_pipeline_train_checkpoint_resume(tmp_path):
    """The full training loop: JoSS-placed data -> train -> crash ->
    resume from the atomic checkpoint -> identical continuation."""
    cfg = get_config("qwen3-4b").smoke().scaled(vocab=128)
    model = build_model(cfg)
    cluster = VirtualCluster([2, 2])
    store = TokenStore(cluster, n_shards=8, seqs_per_shard=16,
                       seq_len=32, vocab=cfg.vocab, seed=0)

    def run(n_steps, resume_from=None):
        pipe = JossDataPipeline(store, global_batch=4, seed=1)
        tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=20))
        step_fn = jax.jit(make_train_step(model, tcfg))
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        start = 0
        if resume_from is not None:
            state, start = ckpt.restore(str(tmp_path),
                                        {"p": params, "o": opt})
            params, opt = state["p"], state["o"]
        losses = []
        for i, b in enumerate(pipe.batches(n_steps)):
            if i < start:
                continue  # deterministic pipeline replays the schedule
            params, opt, m = step_fn(params, opt,
                                     {"tokens": jnp.asarray(b)})
            losses.append(float(m["loss"]))
            ckpt.save(str(tmp_path), i + 1, {"p": params, "o": opt})
        return losses, params

    full_losses, full_params = run(6)
    # simulate a crash after step 3: wipe later checkpoints, resume
    for s in (4, 5, 6):
        import shutil, os
        d = tmp_path / f"step_{s:09d}"
        if d.exists():
            shutil.rmtree(d)
    resumed_losses, resumed_params = run(6, resume_from=True)
    np.testing.assert_allclose(resumed_losses, full_losses[3:], rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(full_params),
                    jax.tree_util.tree_leaves(resumed_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_classification_robust_to_fp_noise():
    """10% measurement noise on FP must not flip benchmark classes whose
    FP is far from td=2 (the paper's memoized-average premise)."""
    res = run_one("joss-t", "small", n_jobs=30, seed=3,
                  config=SimConfig(fp_noise=0.1))
    res_clean = run_one("joss-t", "small", n_jobs=30, seed=3)
    # Permu (FP=3) stays RH -> reduce-locality stays 1.0
    from repro.sim.metrics import summarize
    s_noisy = summarize(res)
    s_clean = summarize(res_clean)
    assert s_noisy.reduce_locality["Permu"] == pytest.approx(1.0)
    assert abs(s_noisy.int_mb - s_clean.int_mb) / s_clean.int_mb < 0.1


def test_failure_detection_to_elastic_replan():
    """Heartbeat loss -> dead pod -> elastic plan excludes it and
    reassigns its shards."""
    cluster = VirtualCluster([4, 4, 4])
    ht = HealthTracker(suspect_after=5, dead_after=10)
    for pod in range(3):
        for i in range(4):
            ht.beat(HostId(pod, i), now=0.0)
    # pod 1 goes silent
    for t in (4.0, 8.0):
        for pod in (0, 2):
            for i in range(4):
                ht.beat(HostId(pod, i), now=t)
    dead = ht.sweep(now=12.0)
    dead_pods = {h.pod for h in dead}
    assert dead_pods == {1}
    alive_pods = sorted({h.pod for h in ht.alive()})
    shard_home = {f"s{i}": i % 3 for i in range(12)}
    plan = plan_elastic_remesh(cluster, alive_pods, shard_home,
                               model_parallel=4)
    assert plan.new_pods == (0, 2)
    assert all(p in (0, 2) for p in plan.orphan_reassignment.values())
    assert len(plan.orphan_reassignment) == 4  # pod 1's shards
