"""The sweep orchestrator's contracts (PR 8): canonical cell keys,
pool-state-free seeding, bit-identical results across worker counts and
submission orders, content-addressed caching, and deterministic
aggregation. The throughput and full-matrix claims run in
``benchmarks/bench_sweep.py``; these tests pin the semantics on tiny
matrices."""
import json
import random

import numpy as np
import pytest

from repro.sweep import (CellSpec, ResultStore, SweepEngine, aggregate,
                         aggregate_json, ci_regressed, code_fingerprint,
                         make_params, matrix, run_cell, run_serial)

#: a tiny-but-real matrix: 2 algorithms x 2 scenarios x 2 seeds of the
#: fabric contention family (each cell is a full simulation, ~tens of ms)
TINY = matrix("fabric_contention", ["fifo", "joss-t"],
              ["uncontended", "oversub8"], 2,
              hosts_per_pod=(4, 4), n_jobs=6)


# ------------------------------------------------------- cell identity --
def test_cell_key_is_canonical_and_round_trips():
    a = CellSpec("fabric_contention", "fifo", "oversub8", 3,
                 make_params(n_jobs=6, hosts_per_pod=(4, 4)))
    b = CellSpec("fabric_contention", "fifo", "oversub8", 3,
                 make_params(hosts_per_pod=[4, 4], n_jobs=6))
    assert a.key() == b.key()          # kwarg order, list vs tuple
    assert CellSpec.from_key(a.key()) == a
    assert CellSpec.from_key(a.key()).key() == a.key()


def test_sim_seed_derives_from_the_whole_key():
    base = CellSpec("f", "a", "s", 0)
    assert base.sim_seed() == CellSpec("f", "a", "s", 0).sim_seed()
    for other in (CellSpec("f", "a", "s", 1), CellSpec("f", "a", "x", 0),
                  CellSpec("f", "b", "s", 0),
                  CellSpec("f", "a", "s", 0, make_params(k=1))):
        assert other.sim_seed() != base.sim_seed()


def test_sim_seed_ignores_global_rng_state():
    spec = TINY[0]
    random.seed(123)
    np.random.seed(123)
    a = spec.sim_seed()
    random.seed(987)
    np.random.seed(987)
    assert spec.sim_seed() == a


def test_run_cell_ignores_global_rng_state():
    """The satellite-3 fix at cell granularity: a cell's metrics are a
    function of its spec alone, whatever the global RNGs held."""
    spec = TINY[0]
    random.seed(1)
    np.random.seed(1)
    a = run_cell(spec)
    random.seed(0xDEAD)
    np.random.seed(0xBEEF)
    assert run_cell(spec) == a


# -------------------------------------------- engine and worker pools --
@pytest.fixture(scope="module")
def inline_results():
    results, stats = SweepEngine(workers=1, store=None).run(TINY)
    assert stats.n_executed == len(TINY)
    return results


def test_pool_of_8_matches_pool_of_1(inline_results):
    """Workers re-derive RNG streams from the cell key and never
    inherit pool state: an 8-worker spawn pool must reproduce the
    inline engine bit-for-bit."""
    pooled, stats = SweepEngine(workers=8, store=None).run(TINY)
    assert stats.workers == 8
    assert pooled == inline_results


def test_shuffled_submission_order_is_invisible(inline_results):
    shuffled = random.Random(7).sample(TINY, len(TINY))
    results, _ = SweepEngine(workers=1, store=None).run(shuffled)
    assert results == inline_results
    assert (aggregate_json(results, metrics=("wtt",))
            == aggregate_json(inline_results, metrics=("wtt",)))


def test_serial_baseline_matches_engine(inline_results):
    assert run_serial(TINY[:2]) == {
        k: inline_results[k] for k in (s.key() for s in TINY[:2])}


def test_duplicate_specs_execute_once():
    results, stats = SweepEngine(workers=1, store=None).run(
        [TINY[0], TINY[0], TINY[0]])
    assert stats.n_cells == 1 and stats.n_executed == 1
    assert list(results) == [TINY[0].key()]


def test_unknown_family_raises():
    with pytest.raises(ValueError, match="unknown cell family"):
        run_cell(CellSpec("no_such_family", "a", "s", 0))


# ------------------------------------------------ content-addressed cache --
def test_store_round_trip_and_cache_hits(tmp_path, inline_results):
    store = ResultStore(directory=str(tmp_path))
    engine = SweepEngine(workers=1, store=store)
    r1, s1 = engine.run(TINY)
    assert (s1.n_executed, s1.n_cached) == (len(TINY), 0)
    r2, s2 = engine.run(TINY)
    assert (s2.n_executed, s2.n_cached) == (0, len(TINY))
    assert r1 == r2 == inline_results   # cache transparency, bit-exact


def test_store_keyed_on_code_fingerprint(tmp_path):
    a = ResultStore(directory=str(tmp_path), fingerprint="a" * 64)
    b = ResultStore(directory=str(tmp_path), fingerprint="b" * 64)
    a.put("cell", {"wtt": 1.0})
    assert a.get("cell") == {"wtt": 1.0}
    assert b.get("cell") is None        # other code version: miss


def test_store_treats_corruption_as_miss(tmp_path):
    store = ResultStore(directory=str(tmp_path), fingerprint="c" * 64)
    store.put("cell", {"wtt": 1.0})
    path = store._path("cell")
    with open(path, "w") as f:
        f.write("{not json")
    assert store.get("cell") is None
    store.put("cell", {"wtt": 2.0})     # overwritable after corruption
    assert store.get("cell") == {"wtt": 2.0}


def test_fingerprint_is_stable_within_a_process():
    assert code_fingerprint() == code_fingerprint()
    assert len(code_fingerprint()) == 64


# ------------------------------------------------- aggregation + gate --
def test_aggregate_is_deterministic_and_keyed():
    vals = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
    a = aggregate(vals, key="k")
    assert a == aggregate(list(reversed(vals)), key="k")
    assert a != aggregate(vals, key="other")    # CI reseeds per key
    assert a["n"] == len(vals)
    assert a["ci_lo"] <= a["mean"] <= a["ci_hi"]
    assert a["p5"] <= a["p50"] <= a["p95"]


def test_aggregate_single_value_degenerates():
    a = aggregate([2.5], key="k")
    assert a["ci_lo"] == a["mean"] == a["ci_hi"] == 2.5


def test_ci_regressed_directions():
    stored = {"ci_lo": 10.0, "ci_hi": 12.0}
    # overlap => never a regression, either direction
    assert not ci_regressed(stored, {"ci_lo": 11.0, "ci_hi": 13.0},
                            higher_is_bad=True)
    assert not ci_regressed(stored, {"ci_lo": 9.0, "ci_hi": 10.5},
                            higher_is_bad=False)
    # disjoint in the bad direction => regression
    assert ci_regressed(stored, {"ci_lo": 12.5, "ci_hi": 14.0},
                        higher_is_bad=True)
    assert ci_regressed(stored, {"ci_lo": 7.0, "ci_hi": 9.5},
                        higher_is_bad=False)
    # disjoint in the good direction => fine
    assert not ci_regressed(stored, {"ci_lo": 7.0, "ci_hi": 9.5},
                            higher_is_bad=True)
    assert not ci_regressed(stored, {"ci_lo": 12.5, "ci_hi": 14.0},
                            higher_is_bad=False)


def test_aggregate_cells_groups_by_scenario_and_algo(inline_results):
    rows = json.loads(aggregate_json(inline_results, metrics=("wtt",)))
    keys = {(r["scenario"], r["algo"], r["metric"]) for r in rows}
    assert keys == {(s, a, "wtt")
                    for s in ("uncontended", "oversub8")
                    for a in ("fifo", "joss-t")}
    assert all(r["n"] == 2 for r in rows)
