"""Data-durability subsystem invariants (PR 3).

Equivalence: a *disabled* durability config leaves elastic runs
bit-identical to the PR 2 simulator (and, with churn also disabled, to
the static simulator) for all five algorithms. Re-replication event
ordering is deterministic per seed, the repair pipeline honors its delay
and bandwidth budget, restored replicas re-patch every locality index
(JoSS queues and baseline host maps), and checkpointed jobs survive host
loss with zero lost work. Plus the PR 2 seam the tentpole builds on: a
churned job whose ready-marks span its original queue and RQ_FIFO after
``evacuate_pod`` + ``mark_job_unready``.
"""
import pytest

from repro.core.job import Job, MapTask, ReduceTask
from repro.core.joss import make_algorithm
from repro.core.queues import ClusterQueues
from repro.core.topology import HostId, Locality, VirtualCluster
from repro.elastic import (BacklogThresholdScaler, ChurnConfig,
                           DurabilityConfig, DurabilityManager,
                           ElasticEngine, PriceSheet)
from repro.sim.cluster_sim import Simulator
from repro.sim.workloads import durability_scenarios, make_cluster, \
    small_workload

from tests.test_elastic import ALGOS, mk_map, run_sim


# --------------------------------------------------------------- helpers --
CHURN_KW = dict(fail_rate=2.0, rejoin_delay=90.0, spot_fraction=0.25,
                spot_preempt_rate=2.0)


def dur_engine(dur_kw, churn_seed=5):
    def factory(cluster):
        return ElasticEngine(
            cluster, churn=ChurnConfig(seed=churn_seed, **CHURN_KW),
            autoscaler=BacklogThresholdScaler(min_hosts=2),
            durability=(None if dur_kw is None
                        else DurabilityConfig(**dur_kw)))
    return factory


# ------------------------------------------------ disabled == PR 2 elastic --
@pytest.mark.parametrize("name", ALGOS)
def test_disabled_durability_is_bit_identical_to_elastic(name):
    """An attached-but-disabled durability config must not perturb churn
    runs at all (no manager is built, no new code path taken)."""
    _, base_m, base_s = run_sim(name, 2, dur_engine(None))
    _, off_m, off_s = run_sim(
        name, 2, dur_engine(dict(rereplicate=False, checkpoint=False)))
    assert base_m == off_m
    assert base_s == off_s


def test_disabled_durability_and_churn_is_static():
    """With churn also disabled the whole elastic+durability stack must
    reduce to the static simulator."""
    _, static_m, static_s = run_sim("joss-t", 3)
    _, stack_m, stack_s = run_sim(
        "joss-t", 3,
        lambda cl: ElasticEngine(cl, durability=DurabilityConfig()))
    assert static_m == stack_m
    assert static_s == stack_s


# ------------------------------------------------------ topology patching --
def test_add_replica_restores_locality():
    cluster = VirtualCluster([2, 2])
    h00, h01, h11 = HostId(0, 0), HostId(0, 1), HostId(1, 1)
    cluster.place_shard("a", [h00])
    cluster.remove_host(h00)
    assert cluster.replica_hosts("a") == frozenset()
    assert cluster.locality_of("a", h01) is Locality.OFF_POD
    cluster.add_replica("a", h01)
    assert cluster.replica_hosts("a") == frozenset({h01})
    assert cluster.replica_pods("a") == [0]
    assert cluster.locality_of("a", h01) is Locality.HOST
    assert cluster.nearest_replica("a", h11) == (h01, Locality.OFF_POD)
    assert "a" in cluster.host(h01).local_shards
    cluster.add_replica("a", h01)           # idempotent
    assert cluster.shard_replicas["a"] == [h01]


# ------------------------------------------------------- repair pipeline --
def test_manager_honors_delay_and_bandwidth_budget():
    """Copies drain serially: copy i completes at
    max(loss + delay, pipeline_free) + size/bandwidth."""
    cluster = VirtualCluster([2, 2])
    h = HostId(0, 0)
    cluster.place_shard("s1", [h])
    cluster.place_shard("s2", [h])
    dead = cluster.remove_host(h)
    mgr = DurabilityManager(
        DurabilityConfig(rereplicate=True, rerep_delay=10.0,
                         rerep_bandwidth=64.0), cluster)
    evs = mgr.host_lost(dead, 100.0, {"s1": 128.0, "s2": 128.0}.get)
    assert [e.shard_id for e in evs] == ["s1", "s2"]   # sorted-id order
    assert evs[0].time == pytest.approx(112.0)         # 100 + 10 + 128/64
    assert evs[1].time == pytest.approx(114.0)         # queued behind s1
    assert mgr.summary.n_rerep_scheduled == 2
    # a second loss queues behind the busy pipeline, not behind its delay
    cluster.place_shard("s3", [HostId(0, 1)])
    dead2 = cluster.remove_host(HostId(0, 1))
    (ev3,) = mgr.host_lost(dead2, 100.0, {"s3": 64.0}.get)
    assert ev3.time == pytest.approx(115.0)            # 114 + 64/64


def test_manager_skips_unknown_size_shards():
    """Shards outside the simulated workload (profiling-prelude
    placements) are not worth repair bandwidth."""
    cluster = VirtualCluster([2, 2])
    cluster.place_shard("known", [HostId(0, 0)])
    cluster.place_shard("prelude", [HostId(0, 0)])
    dead = cluster.remove_host(HostId(0, 0))
    mgr = DurabilityManager(DurabilityConfig(rereplicate=True), cluster)
    evs = mgr.host_lost(dead, 0.0, {"known": 128.0}.get)
    assert [e.shard_id for e in evs] == ["known"]


def test_manager_target_prefers_lost_pod_then_least_loaded():
    cluster = VirtualCluster([3, 2])
    cluster.place_shard("x", [HostId(0, 0)])
    cluster.place_shard("ballast", [HostId(0, 1)])    # loads host (0,1)
    dead = cluster.remove_host(HostId(0, 0))
    mgr = DurabilityManager(DurabilityConfig(rereplicate=True), cluster)
    (ev,) = mgr.host_lost(dead, 0.0, {"x": 128.0,
                                      "ballast": 128.0}.get)
    target, pod_covered = mgr.apply(ev)
    # pod 0 preferred (it lost the replica); (0,1) holds a shard already,
    # so the empty (0,2) wins; the pod had lost all coverage
    assert target == HostId(0, 2)
    assert pod_covered is False
    assert cluster.locality_of("x", target) is Locality.HOST
    assert mgr.summary.n_rerep == 1
    assert mgr.summary.rerep_mb == pytest.approx(128.0)


def test_manager_apply_skips_when_every_host_holds_the_shard():
    cluster = VirtualCluster([1, 1])
    cluster.place_shard("x", [HostId(0, 0), HostId(1, 0)])
    dead = cluster.remove_host(HostId(1, 0))
    mgr = DurabilityManager(DurabilityConfig(rereplicate=True), cluster)
    (ev,) = mgr.host_lost(dead, 0.0, {"x": 128.0}.get)
    assert mgr.apply(ev) is None          # only live host already holds it
    assert mgr.summary.n_rerep_skipped == 1


# ------------------------------------------------- locality index repatch --
def test_queue_reindex_restores_host_and_pod_entries():
    cluster = VirtualCluster([2, 2])
    h00, h01 = HostId(0, 0), HostId(0, 1)
    cluster.place_shard("s", [h00])
    cluster.remove_host(h00)              # replica gone before enqueue
    queues = ClusterQueues(cluster)
    t = mk_map(1, 0, "s")
    queues.pods[0].mq0.append(t)
    assert queues.pods[0].mq0.peek_local(1, h01) is None
    assert queues.pods[0].mq0.peek_pod(1, 0) is None
    cluster.add_replica("s", h01)
    queues.replica_restored("s", h01, pod_covered=False)
    assert queues.pods[0].mq0.peek_local(1, h01) is t
    assert queues.pods[0].mq0.peek_pod(1, 0) is t
    # the restored entries are real picks, and picking drains both indexes
    assert queues.pods[0].mq0.pick_local(1, h01) is t
    assert queues.pods[0].mq0.peek_pod(1, 0) is None


def test_joss_replica_restored_reaches_requeued_fifo_tasks():
    """A churn-requeued map in MQ_FIFO (zero surviving replicas at requeue
    time) regains host locality when the repair copy lands."""
    cluster = VirtualCluster([2, 2])
    h00, h10 = HostId(0, 0), HostId(1, 0)
    cluster.place_shard("s", [h00])
    cluster.remove_host(h00)
    algo = make_algorithm("joss-t", cluster)
    retry = MapTask(9, 0, "s", 128, attempt=1)
    algo.requeue_map_task(retry)
    fifo = algo.scheduler.queues.mq_fifo
    assert fifo.peek_local(9, h10) is None
    cluster.add_replica("s", h10)
    algo.replica_restored("s", h10, pod_covered=False)
    assert fifo.peek_local(9, h10) is retry


def test_baseline_replica_restored_indexes_pending_maps():
    cluster = VirtualCluster([2, 2])
    h00, h11 = HostId(0, 0), HostId(1, 1)
    cluster.place_shard("b0/s", [h00])
    algo = make_algorithm("fifo", cluster)
    job = Job(name="b", code_key="c", input_type="web",
              shard_ids=["b0/s"], shard_bytes=[128.0], n_reducers=1)
    cluster.remove_host(h00)
    algo.host_lost(h00)
    algo.submit(job)
    assert algo.next_map_task(h11) is job.map_tasks[0]  # non-local fallback
    cluster.add_replica("b0/s", h11)
    algo.replica_restored("b0/s", h11, pod_covered=False)
    local = algo._host_maps.get((job.job_id, h11))
    assert local is not None and local[0] is job.map_tasks[0]


# ----------------------------------------------------------- end to end --
def test_rerep_runs_complete_and_are_deterministic():
    """Re-replication event ordering (and everything downstream) is a pure
    function of (workload seed, churn seed)."""
    kw = durability_scenarios()["rerep"]
    res_a, met_a, seq_a = run_sim("joss-t", 6, dur_engine(kw))
    res_b, met_b, seq_b = run_sim("joss-t", 6, dur_engine(kw))
    assert met_a == met_b and seq_a == seq_b
    assert res_a.n_rerep == res_b.n_rerep
    assert res_a.rerep_mb == res_b.rerep_mb
    assert res_a.n_rerep > 0, "scenario produced no repairs"
    assert len(res_a.job_finish) == len(res_a.jobs)


@pytest.mark.parametrize("name", ("joss-j", "fair"))
def test_ckpt_runs_lose_no_finished_work(name):
    res, _, _ = run_sim(name, 1, dur_engine(durability_scenarios()["ckpt"]))
    base, _, _ = run_sim(name, 1, dur_engine(None))
    assert base.n_host_losses > 0
    assert base.work_lost_mb > 0          # churn does destroy work...
    assert res.work_lost_mb == 0.0        # ...unless outputs checkpoint
    assert res.ckpt_mb_written > 0
    assert res.storage_dollars > 0
    # the store bill is folded into the tenant's total
    assert res.cost_dollars == pytest.approx(res.elastic.cost)
    assert len(res.job_finish) == len(res.jobs)


def test_ckpt_storage_priced_by_sheet():
    cluster = VirtualCluster([2])
    mgr = DurabilityManager(
        DurabilityConfig(checkpoint=True), cluster,
        prices=PriceSheet(storage_per_gb=1.0))
    mgr.note_ckpt_write(2048.0)
    assert mgr.storage_cost() == pytest.approx(2.0)
    assert mgr.finalize().storage_dollars == pytest.approx(2.0)


def test_ckpt_min_job_mb_filters_small_jobs():
    cluster = VirtualCluster([2])
    mgr = DurabilityManager(
        DurabilityConfig(checkpoint=True, ckpt_min_job_mb=1000.0), cluster)
    small = Job(name="s", code_key="c", input_type="web",
                shard_ids=["s/0"], shard_bytes=[128.0], n_reducers=1)
    big = Job(name="b", code_key="c", input_type="web",
              shard_ids=[f"b/{i}" for i in range(10)],
              shard_bytes=[128.0] * 10, n_reducers=1)
    assert not mgr.checkpoints_job(small)
    assert mgr.checkpoints_job(big)
    assert mgr.checkpoints_job(big)       # cached path


def test_full_durability_under_paper_workload():
    """Both channels together on the paper workload: every job finishes,
    nothing is lost, repairs happen, and the run is deterministic."""
    kw = durability_scenarios()["full"]

    def once():
        cluster = make_cluster((4, 4))
        jobs = small_workload(cluster, seed=5, n_jobs=10)
        algo = make_algorithm("joss-j", cluster)
        eng = ElasticEngine(
            cluster, churn=ChurnConfig(seed=2, fail_rate=2.0,
                                       rejoin_delay=120.0),
            durability=DurabilityConfig(**kw))
        return Simulator(cluster, algo, jobs, seed=5, elastic=eng).run()

    a, b = once(), once()
    assert a.n_host_losses > 0
    assert a.work_lost_mb == 0.0
    assert a.n_rerep > 0
    assert len(a.job_finish) == len(a.jobs)
    assert (a.wtt, a.n_rerep, a.rerep_mb, a.ckpt_mb_written,
            a.cost_dollars) == (b.wtt, b.n_rerep, b.rerep_mb,
                                b.ckpt_mb_written, b.cost_dollars)


# ------------------------------------------- PR 2 seam (satellite cover) --
def test_split_ready_marks_survive_evacuate_and_unready_cycle():
    """A churned job whose reduce buckets span its original pod queue and
    RQ_FIFO (requeue) and then lose their pod (evacuate) must keep gate
    notifications coherent across every holding queue: unready closes
    all of them, ready reopens all of them."""
    cluster = VirtualCluster([2, 2])
    algo = make_algorithm("joss-t", cluster)
    queues = algo.scheduler.queues
    rq = queues.pods[0].rq0
    originals = [ReduceTask(7, 0), ReduceTask(7, 1)]
    rq.extend(originals)
    queues.register_reduce_queue(7, rq)
    retry = ReduceTask(7, 2, attempt=1)
    algo.requeue_reduce_task(retry)           # marks span rq0 and RQ_FIFO
    queues.mark_job_ready(7)
    never = lambda t: False
    # churn re-closes the gate: nothing pickable anywhere
    queues.mark_job_unready(7)
    assert queues.rq_fifo.pick_ready(never, trust_marks=True) is None
    assert rq.pick_ready(never, trust_marks=True) is None
    # pod 0 dies: the original bucket evacuates to RQ_FIFO, still gated
    cluster.remove_host(HostId(0, 0))
    cluster.remove_host(HostId(0, 1))
    algo.host_lost(HostId(0, 0))
    algo.host_lost(HostId(0, 1))              # evacuates pod 0
    assert len(queues.rq_fifo) == 3
    assert queues.rq_fifo.pick_ready(never, trust_marks=True) is None
    # re-runs land, the gate reopens: every reduce is served from RQ_FIFO
    queues.mark_job_ready(7)
    picked = [queues.rq_fifo.pick_ready(never, trust_marks=True)
              for _ in range(3)]
    assert set(id(t) for t in picked) == set(
        id(t) for t in originals + [retry])
    assert queues.rq_fifo.pick_ready(never, trust_marks=True) is None
