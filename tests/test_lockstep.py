"""The lockstep batched executor (PR 9 tentpole): live simulators
driven in synchronized epochs with their fabric fills solved through
the batched vmap kernel must be **bit-identical** — not bit-close — to
the scalar ``run_cell`` path: same per-cell metric dicts (completion
orderings included; the metrics are completion-derived), same
aggregate claim JSON bytes, under any gang size, with and without jax.
The deferred-fill protocol itself is exercised at both ends: the
inline backend as the equivalence anchor, and the settle guard that
refuses to advance time across an undelivered fill."""
import pytest

from repro.sim.network import InlineFillBackend
from repro.sweep import (LockstepExecutor, ResultStore, SweepEngine,
                         aggregate_json, matrix, run_cell)
from repro.sweep.cells import build_fabric_contention
from repro.sweep.lockstep import DeferredFillBackend
from repro.sweep.vmap_fill import HAVE_JAX

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax unavailable")

#: the bench gate operating point (8 pods x 8 hosts, 24 jobs): fills
#: span enough classes that the batched kernel actually engages — at
#: smaller points every problem falls under the INLINE_C scalar route
#: and the kernel path would go untested
GATE = dict(hosts_per_pod=(8,) * 8, n_jobs=24)


def _specs(n_seeds=2, algos=("joss-t", "fifo"),
           scenarios=("oversub8", "uncontended")):
    return matrix("fabric_contention", algos, scenarios, n_seeds,
                  **GATE)


@pytest.fixture(scope="module")
def scalar_results():
    """The ground truth: every cell through the plain scalar path."""
    return {s.key(): run_cell(s) for s in _specs()}


# ------------------------------------------------ deferred protocol --
def test_inline_backend_is_trajectory_identical(scalar_results):
    """The equivalence anchor: a fabric with the inline deferred
    backend (defer -> solve immediately) reproduces the no-backend run
    bit-for-bit — deferral itself changes nothing."""
    spec = _specs()[0]
    sim, finish = build_fabric_contention(spec)
    sim.begin()
    backend = InlineFillBackend(timed=True)
    sim.fabric.fill_backend = backend
    res = finish(sim.finish(sim.step()))
    assert res == scalar_results[spec.key()]
    assert backend.n_fills > 0 and backend.fill_s > 0.0


def test_settle_guard_refuses_undelivered_fill():
    """A backend that defers and never delivers must be caught at the
    next dt>0 settle, not silently integrate stale rates."""
    sim, _ = build_fabric_contention(_specs()[0])
    sim.begin()
    sim.fabric.fill_backend = DeferredFillBackend()
    with pytest.raises(RuntimeError, match="deferred fill"):
        sim.step()          # no pause predicate: nothing delivers


def _deferred_fabric():
    """A bare fabric with a pending deferred fill (no simulator): one
    flow started under the deferred backend leaves fill_pending set."""
    from repro.core.topology import LinkCapacities
    from repro.sim.engine import EventKernel
    from repro.sim.network import NetworkFabric
    from repro.sim.workloads import make_cluster

    class _Sim:
        pass
    cluster = make_cluster((2, 2),
                           links=LinkCapacities(pod_up=1e6, pod_down=1e6,
                                                wan=100.0))
    fab = NetworkFabric(cluster)
    fab.attach(_Sim(), EventKernel())
    fab.fill_backend = DeferredFillBackend()
    fab.start_flow(0.0, 50.0, 0, 1, cap=1e6, kind="t",
                   done=lambda now: None)
    assert fab.fill_pending
    return fab


def test_settle_time_advance_guard_direct():
    """The ``_settle`` guard itself (PR 10 satellite — previously only
    reachable through the executor): advancing simulated time across an
    undelivered fill raises; a dt == 0 re-settle of the same instant is
    legal (the barrier settles before delivering)."""
    fab = _deferred_fabric()
    fab._settle(0.0)        # same instant: no integration, no error
    assert fab.fill_pending
    with pytest.raises(RuntimeError,
                       match="time advanced across a deferred fill"):
        fab._settle(1.0)
    # delivery clears the flag and time may advance again
    fab.solve_fill_inline()
    assert not fab.fill_pending
    fab._settle(1.0)


def test_fill_delivery_without_pending_raises():
    """Both delivery entry points refuse to run with no deferred fill
    outstanding — a double delivery would re-arm from stale state."""
    fab = _deferred_fabric()
    fab.solve_fill_inline()
    with pytest.raises(RuntimeError, match="no fill pending"):
        fab.solve_fill_inline()
    with pytest.raises(RuntimeError, match="no fill pending"):
        fab.apply_fill([0.0])


# ------------------------------------------------- executor (no jax) --
def test_executor_scalar_path_matches_run_cell(scalar_results):
    ex = LockstepExecutor(use_jax=False)
    res = ex.run(_specs())
    assert res == scalar_results
    assert not ex.stats.used_jax
    assert ex.stats.n_cells == len(scalar_results)
    assert ex.stats.n_fallback == 0
    assert ex.stats.problems > 0 and ex.stats.epochs > 0


def test_executor_falls_back_on_unbatchable_family(scalar_results):
    """Families without a lockstep builder run through scalar
    run_cell inside the executor — mixed matrices still work."""
    fabric = _specs(n_seeds=1)
    elastic = matrix("elastic_churn", ("fifo",), ("flaky",), 1,
                     fleet=(4, 4), n_jobs=12)
    ex = LockstepExecutor(use_jax=False)
    res = ex.run(fabric + elastic)
    assert ex.stats.n_fallback == len(elastic)
    for s in fabric:
        assert res[s.key()] == scalar_results[s.key()]
    for s in elastic:
        assert res[s.key()] == run_cell(s)


# --------------------------------------------------- executor (jax) --
@needs_jax
def test_executor_batched_path_bit_identical(scalar_results):
    """The tentpole contract: metrics equal the scalar runs exactly
    and the aggregate claim JSON is byte-identical."""
    ex = LockstepExecutor()
    res = ex.run(_specs())
    assert ex.stats.used_jax
    assert res == scalar_results
    assert (aggregate_json(res)
            == aggregate_json(scalar_results))   # byte-identical


@needs_jax
def test_gang_size_never_changes_results(scalar_results):
    """Batch composition is an implementation detail: a gang of 2
    (many small batches, heavy refill churn) and a gang of 64 (one
    batch per epoch) produce identical bytes."""
    small = LockstepExecutor(gang_size=2).run(_specs())
    large = LockstepExecutor(gang_size=64).run(_specs())
    assert small == large == scalar_results


@needs_jax
def test_executor_accounts_batches_and_inlining():
    ex = LockstepExecutor()
    ex.run(_specs(n_seeds=1))
    st = ex.stats
    assert st.batches > 0 and st.fill_s > 0.0
    # both routes exercised: some problems inlined (<= INLINE_C
    # classes), the rest batched through the kernel
    assert 0 < st.inline_small < st.problems


# ------------------------------------------------- engine integration --
def test_engine_lockstep_backend_matches_pool(tmp_path, scalar_results):
    """``SweepEngine(backend="lockstep")`` is a drop-in: same results,
    same store entries — a lockstep-populated cache serves a pool
    engine and vice versa."""
    specs = _specs(n_seeds=1)
    store = ResultStore(str(tmp_path))
    engine = SweepEngine(store=store, backend="lockstep")
    res, stats = engine.run(specs)
    assert engine.lockstep_stats is not None
    assert engine.lockstep_stats.n_cells == len(specs)
    assert res == {s.key(): scalar_results[s.key()] for s in specs}
    # warm re-run through a *pool* engine: served from the same store
    res2, stats2 = SweepEngine(store=store, backend="pool").run(specs)
    assert stats2.n_executed == 0 and res2 == res


def test_engine_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        SweepEngine(backend="warp")


# ---------------------------------------- fills_dropped (satellite) --
def _capture_run(capture: int):
    """A small contended run with a fill-capture budget (the lockstep
    builder hardcodes its config, so construct the cell by hand)."""
    from repro.core.joss import make_algorithm
    from repro.sim.cluster_sim import SimConfig, Simulator
    from repro.sim.network import FabricConfig
    from repro.sim.workloads import (fabric_links, make_cluster,
                                     small_workload)
    links = fabric_links((8, 8), wan_oversub=8.0)
    cluster = make_cluster((8, 8), links=links)
    jobs = small_workload(cluster, seed=7, n_jobs=12)
    for j in jobs:
        j.submit_time = 0.0
    cfg = SimConfig(fabric=FabricConfig(completion_log=False,
                                        capture_fills=capture))
    sim = Simulator(cluster, make_algorithm("fifo", cluster), jobs,
                    config=cfg, seed=7)
    sim.run()
    return sim.fabric


def test_fills_dropped_counts_past_capture_budget():
    """``fills_dropped`` mirrors ``log_dropped``: solves past the
    ``capture_fills`` budget are counted, never silently elided — a
    truncated corpus is visible as snapshots + dropped = total."""
    fabric = _capture_run(capture=5)
    assert len(fabric.fill_snapshots) == 5
    assert fabric.summary.fills_dropped > 0


def test_fills_dropped_zero_when_capture_disabled():
    fabric = _capture_run(capture=0)
    assert fabric.fill_snapshots == []
    assert fabric.summary.fills_dropped == 0
