"""JoSS data pipeline locality + serve router affinity/failover."""
import numpy as np
import pytest

from repro.core.topology import VirtualCluster
from repro.data import JossDataPipeline, TokenStore
from repro.serve import JossServeRouter, Request


def make_store(seed=0, k=2, hosts=4, n_shards=32):
    cluster = VirtualCluster([hosts] * k)
    store = TokenStore(cluster, n_shards=n_shards, seqs_per_shard=8,
                       seq_len=16, vocab=100, replication=1, seed=seed)
    return cluster, store


def test_pipeline_batches_shape_and_determinism():
    _, store = make_store()
    pipe = JossDataPipeline(store, global_batch=8, seed=1)
    batches = list(pipe.batches(3))
    assert all(b.shape == (8, 16) for b in batches)
    pipe2 = JossDataPipeline(store, global_batch=8, seed=1)
    for a, b in zip(batches, pipe2.batches(3)):
        np.testing.assert_array_equal(a, b)


def test_joss_placement_beats_blind_placement():
    """Policy-B shard->pod assignment: every batch read is pod-local
    (Cen-locality); the placement-blind baseline leaks off-pod reads."""
    _, store = make_store(seed=3)
    joss = JossDataPipeline(store, global_batch=8, seed=2, joss=True)
    for _ in joss.batches(50):
        pass
    rep_joss = joss.locality_report()

    _, store2 = make_store(seed=3)
    blind = JossDataPipeline(store2, global_batch=8, seed=2, joss=False)
    for _ in blind.batches(50):
        pass
    rep_blind = blind.locality_report()

    assert rep_joss.off_pod_rate <= 1e-9          # policy B: all local
    assert rep_blind.off_pod_rate > 0.2           # blind leaks off-pod
    assert rep_joss.int_bytes < rep_blind.int_bytes


def test_router_session_affinity():
    cluster = VirtualCluster([2, 2])
    r = JossServeRouter(cluster)
    d1 = r.route(Request("r1", session="s1", prompt_tokens=100))
    assert d1.policy == "A" and not d1.cache_hit
    d2 = r.route(Request("r2", session="s1", prompt_tokens=10))
    assert d2.policy == "B" and d2.cache_hit
    assert d2.pod == d1.pod                      # KV affinity
    assert r.cache_hit_rate() == pytest.approx(0.5)


def test_router_least_loaded_for_fresh():
    cluster = VirtualCluster([2, 2])
    r = JossServeRouter(cluster)
    a = r.route(Request("a", session=None, prompt_tokens=1000))
    b = r.route(Request("b", session=None, prompt_tokens=10))
    assert b.pod != a.pod                        # pod 0 loaded -> pod 1


def test_router_failover_invalidates_sessions():
    cluster = VirtualCluster([2, 2])
    r = JossServeRouter(cluster)
    d = r.route(Request("r1", session="s1", prompt_tokens=10))
    lost = r.pod_failed(d.pod)
    assert lost == ["s1"]
    d2 = r.route(Request("r2", session="s1", prompt_tokens=10))
    assert not d2.cache_hit                      # re-enters as fresh
