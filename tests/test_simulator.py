"""Discrete-event simulator: reproduces the paper's §6 claims at reduced
scale, plus framework-level invariants (conservation, determinism,
straggler mitigation)."""
import numpy as np
import pytest

from repro.core.job import MapTask
from repro.core.topology import Locality
from repro.sim.cluster_sim import SimConfig, Simulator
from repro.sim.experiment import run_comparison, run_one
from repro.sim.metrics import summarize

N_JOBS = 40  # reduced small-workload (full 300 runs in benchmarks/)


@pytest.fixture(scope="module")
def small_results():
    return run_comparison("small", n_jobs=N_JOBS, seed=7)


def test_all_jobs_complete(small_results):
    for name in ("joss-t", "joss-j", "fifo"):
        res = run_one(name, "small", n_jobs=10, seed=3)
        assert len(res.job_finish) == 10
        for j in res.jobs:
            assert j.done()


def test_determinism():
    a = run_one("joss-t", "small", n_jobs=10, seed=5)
    b = run_one("joss-t", "small", n_jobs=10, seed=5)
    assert a.int_bytes == b.int_bytes
    assert a.wtt == b.wtt


def test_paper_claim_map_locality(small_results):
    """Fig. 7: JoSS variants' off-Cen rate ~0 for MH benchmarks, far below
    the Hadoop baselines."""
    for bench in ("WC", "SC", "II", "Grep"):
        for joss in ("joss-t", "joss-j"):
            off_joss = small_results[joss].map_locality[bench].off_cen
            assert off_joss <= 0.05, (joss, bench, off_joss)
        off_fifo = small_results["fifo"].map_locality[bench].off_cen
        assert off_fifo > 0.05, (bench, off_fifo)


def test_paper_claim_reduce_locality(small_results):
    """Fig. 8: JoSS reduce-data locality = 1.0 for RH jobs (policy A) and
    above every baseline overall."""
    for joss in ("joss-t", "joss-j"):
        assert small_results[joss].reduce_locality["Permu"] == \
            pytest.approx(1.0)
    for bench in ("WC", "SC", "II", "Grep", "Permu"):
        jo = min(small_results["joss-t"].reduce_locality[bench],
                 small_results["joss-j"].reduce_locality[bench])
        for base in ("fifo", "fair", "capacity"):
            assert jo >= small_results[base].reduce_locality[bench] - 1e-9


def test_paper_claim_int(small_results):
    """Fig. 9: JoSS INT far below the baselines (paper: ~1/3)."""
    for joss in ("joss-t", "joss-j"):
        for base in ("fifo", "fair", "capacity"):
            assert small_results[joss].int_mb < \
                0.75 * small_results[base].int_mb


def test_paper_claim_jtt_small_workload(small_results):
    """Fig. 10 / Table 8: JoSS-T has the best (or tied-best) mean JTT."""
    mean_jtt = {name: np.mean(list(s.avg_jtt.values()))
                for name, s in small_results.items()}
    best = min(mean_jtt.values())
    assert mean_jtt["joss-t"] <= best * 1.05


def test_traffic_conservation():
    """Every byte is read exactly once per map task: host+pod+off bytes sum
    to the workload's total input (+ shuffle bytes for reducers)."""
    res = run_one("joss-t", "small", n_jobs=10, seed=9)
    maps = [l for l in res.task_logs if isinstance(l.task, MapTask)]
    total_in = sum(l.bytes_local + l.bytes_pod + l.bytes_offpod
                   for l in maps)
    expect = sum(j.s_map for j in res.jobs)
    assert total_in == pytest.approx(expect, rel=1e-9)


def test_slot_capacity_never_exceeded():
    res = run_one("joss-j", "small", n_jobs=12, seed=11)
    events = []
    for l in res.task_logs:
        kind = "m" if isinstance(l.task, MapTask) else "r"
        events.append((l.start, 1, kind, l.host))
        events.append((l.finish, -1, kind, l.host))
    events.sort(key=lambda e: (e[0], e[1]))
    load = {}
    for t, d, kind, host in events:
        key = (kind, host)
        load[key] = load.get(key, 0) + d
        assert load[key] <= 1, f"slot oversubscribed at {t} on {host}"


def test_straggler_speculation_reduces_wtt():
    """A 6x-slow host prolongs the run; speculative execution must win
    back a significant share (straggler mitigation)."""
    from repro.core.topology import HostId
    slow = {HostId(0, 0): 6.0}
    base = run_one("joss-t", "small", n_jobs=12, seed=13,
                   config=SimConfig(slow_hosts=slow, speculative=False))
    spec = run_one("joss-t", "small", n_jobs=12, seed=13,
                   config=SimConfig(slow_hosts=slow, speculative=True))
    assert spec.wtt <= base.wtt  # never worse
