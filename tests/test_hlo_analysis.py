"""HLO cost analyzer: trip-count multiplication, dot FLOPs, collective
wire-byte accounting — validated on real lowered programs and on crafted
HLO snippets for the collective factors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (analyze_hlo, parse_computations,
                                       roofline_from_hlo, shape_bytes)


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[4096]") == 8192
    assert shape_bytes("(f32[2,2]{1,0}, s32[4])") == 16 + 16
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("token[]") == 0


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def step(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, None, length=13)
        return y

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    t = analyze_hlo(c.as_text(), 1)
    expect = 13 * 2 * 64 * 128 * 128
    assert t.flops == pytest.approx(expect, rel=1e-6)


def test_nested_scan_trip_counts():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    t = analyze_hlo(c.as_text(), 1)
    expect = 5 * 3 * 2 * 32 * 64 * 64
    assert t.flops == pytest.approx(expect, rel=1e-6)


def test_grad_doubles_flops_roughly():
    def loss(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    g = jax.jit(jax.grad(loss, argnums=1))
    c = g.lower(jax.ShapeDtypeStruct((64, 128), jnp.float32),
                jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    t = analyze_hlo(c.as_text(), 1)
    fwd = 2 * 64 * 128 * 128
    assert t.flops >= 2 * fwd * 0.9  # fwd + dgrad (no wgrad for x)


CRAFTED = """
HloModule crafted

ENTRY %main (p0: f32[1024]) -> f32[64] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = f32[1024]{0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %cp = f32[1024]{0} collective-permute(%ag), source_target_pairs={{0,1}}
  ROOT %rs = f32[64]{0} reduce-scatter(%cp), replica_groups=[16,16]<=[256], to_apply=%add
}
"""


def test_collective_wire_bytes_factors():
    t = analyze_hlo(CRAFTED, 256)
    b = 1024 * 4
    frac = 15 / 16
    assert t.per_collective["all-reduce"] == pytest.approx(2 * frac * b)
    assert t.per_collective["all-gather"] == pytest.approx(frac * b)
    assert t.per_collective["collective-permute"] == pytest.approx(b)
    # reduce-scatter wire = (N-1)/N * operand (= N x result)
    assert t.per_collective["reduce-scatter"] == pytest.approx(frac * b)
    assert t.n_collectives == {"all-reduce": 1, "all-gather": 1,
                               "collective-permute": 1,
                               "reduce-scatter": 1}


def test_narrowing_undoes_cpu_upcast():
    """all-gather of convert(bf16 x) counts bf16 wire bytes (TPU native)."""
    crafted = """
HloModule up

ENTRY %main (p0: bf16[64]) -> f32[1024] {
  %p0 = bf16[64]{0} parameter(0)
  %wide_convert = f32[64]{0} convert(%p0)
  ROOT %ag = f32[1024]{0} all-gather(%wide_convert), replica_groups=[16,16]<=[256], dimensions={0}
}
"""
    t = analyze_hlo(crafted, 256)
    frac = 15 / 16
    # operand counted at bf16 width: (N-1)/N * N * 64 * 2B, not * 4B
    assert t.per_collective["all-gather"] == pytest.approx(
        frac * 16 * 64 * 2)


def test_roofline_terms_and_dominance():
    def f(x, w):
        return x @ w

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8192, 8192), jnp.bfloat16),
        jax.ShapeDtypeStruct((8192, 8192), jnp.bfloat16)).compile()
    rl = roofline_from_hlo(c.as_text(), 1, model_flops_global=2 * 8192**3)
    assert rl.compute_s > 0
    assert rl.dominant in ("compute", "memory")
    assert 0.5 < rl.useful_flop_fraction <= 1.2


def test_dus_counted_as_update_not_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    c = jax.jit(f, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((4096, 4096), jnp.float32),
        jax.ShapeDtypeStruct((4, 4096), jnp.float32)).compile()
    t = analyze_hlo(c.as_text(), 1)
    upd_bytes = 4 * 4096 * 4
    # the DUS itself moves only the update (copies, if any, are separate)
    assert t.mem_by_op.get("dus", 0) <= 2 * upd_bytes
    buf_bytes = 4096 * 4096 * 4
    assert t.mem_bytes < buf_bytes  # donated buffer: no defensive copy
