"""The O(1) indexed scheduling fast path must be behaviour-identical to the
retained naive reference (repro.core.reference): equivalence over randomized
workloads for every algorithm, plus unit tests for the TaskQueue internals
(tombstone removal, locality-index updates, the ready-reduce transition) and
the simulator's backlog-gated dispatch."""
import random

import pytest

from repro.core.assigners import JTA, TTA, fifo_pick_map
from repro.core.job import Job, MapTask, ReduceTask
from repro.core.joss import make_algorithm
from repro.core.queues import ClusterQueues, TaskQueue
from repro.core.reference import (ReferenceJTA, ReferenceTTA,
                                  make_reference_algorithm,
                                  reference_fifo_pick_map)
from repro.core.topology import HostId, VirtualCluster
from repro.sim.cluster_sim import SimConfig, Simulator
from repro.sim.workloads import (make_cluster, profiling_prelude,
                                 small_workload)

ALGOS = ("joss-t", "joss-j", "fifo", "fair", "capacity")


# --------------------------------------------------------------- helpers --
def random_cluster_and_jobs(seed: int, n_jobs: int = 12):
    """A random topology + workload with replicated shards (the paper uses
    1 replica; replication > 1 exercises the multi-replica index paths)."""
    rng = random.Random(seed)
    k = rng.randint(2, 4)
    cluster = VirtualCluster([rng.randint(2, 6) for _ in range(k)])
    hosts = [h.hid for h in cluster.hosts()]
    jobs = []
    for j in range(n_jobs):
        m = rng.randint(1, 10)
        sids = [f"s{seed}/{j}/{b}" for b in range(m)]
        for s in sids:
            reps = rng.sample(hosts, rng.randint(1, min(3, len(hosts))))
            cluster.place_shard(s, reps)
        jobs.append(Job(
            name=f"j{j}", code_key=f"code{j % 4}", input_type="web",
            shard_ids=sids, shard_bytes=[128.0] * m,
            n_reducers=rng.randint(1, 3),
            true_fp=rng.choice([0.1, 0.6, 1.0, 3.0]),
            submit_time=rng.random() * 60.0))
    return cluster, jobs


def run_sim(factory, name, cluster_jobs_seed, config=None):
    cluster, jobs = random_cluster_and_jobs(cluster_jobs_seed)
    idx = {j.job_id: i for i, j in enumerate(jobs)}
    algo = factory(name, cluster)
    if hasattr(algo, "registry"):
        # warm FP registry for half the job codes: exercises both the
        # FIFO-profiling path and the policy A/B/C paths
        for j in jobs:
            if j.code_key in ("code0", "code1"):
                algo.registry.record(j, j.true_fp)
    res = Simulator(cluster, algo, jobs, config=config, seed=7).run()
    seq = [((log.task.tid[0], idx[log.task.tid[1]], *log.task.tid[2:]),
            (log.host.pod, log.host.index), log.start, log.finish,
            log.locality, log.bytes_local, log.bytes_pod, log.bytes_offpod)
           for log in res.task_logs]
    metrics = (res.wtt, res.int_bytes, res.pod_bytes,
               sorted((idx[k], v) for k, v in res.job_finish.items()))
    return metrics, seq


def mk_map(job_id, index, shard):
    return MapTask(job_id, index, shard, 128)


# ------------------------------------------------- equivalence properties --
@pytest.mark.parametrize("name", ALGOS)
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_simulation_equivalence_randomized(name, seed):
    """Indexed and reference stacks produce identical SimResult metrics AND
    identical per-task assignment sequences on randomized workloads."""
    fast_metrics, fast_seq = run_sim(make_algorithm, name, seed)
    ref_metrics, ref_seq = run_sim(make_reference_algorithm, name, seed)
    assert fast_metrics == ref_metrics
    assert fast_seq == ref_seq


@pytest.mark.parametrize("name", ("joss-t", "joss-j"))
def test_simulation_equivalence_paper_workload(name):
    """Same check on the paper's small workload (policies A/B/C mix)."""
    def run(factory):
        cluster = make_cluster((4, 4))
        jobs = small_workload(cluster, seed=5, n_jobs=12)
        idx = {j.job_id: i for i, j in enumerate(jobs)}
        algo = factory(name, cluster)
        for j in profiling_prelude(cluster):
            algo.registry.record(j, j.true_fp)
        res = Simulator(cluster, algo, jobs, seed=5).run()
        return (res.wtt, res.int_bytes, res.pod_bytes,
                [((log.task.tid[0], idx[log.task.tid[1]],
                   *log.task.tid[2:]), log.host, log.start)
                 for log in res.task_logs])
    assert run(make_algorithm) == run(make_reference_algorithm)


@pytest.mark.parametrize("assigner_pair", [(TTA, ReferenceTTA),
                                           (JTA, ReferenceJTA)])
def test_assigner_pick_sequence_equivalence(assigner_pair):
    """Drive indexed and reference assigners directly through a scripted
    sequence of slot offers (including JTA defer churn) and require the
    exact same pick sequence."""
    fast_cls, ref_cls = assigner_pair
    rng = random.Random(99)
    picks = []
    for cls in (fast_cls, ref_cls):
        rng2 = random.Random(42)
        cluster = VirtualCluster([3, 3])
        hosts = [h.hid for h in cluster.hosts()]
        queues = ClusterQueues(cluster)
        if not cls.needs_task_index:
            queues.set_map_task_indexing(False)
        assigner = cls(cluster, queues)
        tasks = []
        for j in range(6):
            for b in range(rng2.randint(1, 5)):
                sid = f"q/{j}/{b}"
                cluster.place_shard(sid, rng2.sample(hosts, 2))
                tasks.append(mk_map(j, b, sid))
        # two jobs through MQ_FIFO, the rest spread over pod queues
        for t in tasks:
            if t.job_id < 2:
                queues.mq_fifo.append(t)
            else:
                queues.pods[t.job_id % 2].mq0.append(t)
        seq = []
        for _ in range(3 * len(tasks)):
            hid = hosts[rng2.randrange(len(hosts))]
            got = assigner.next_map_task(hid)
            seq.append(None if got is None else (got.job_id, got.index))
        picks.append(seq)
    assert picks[0] == picks[1]
    assert any(p is not None for p in picks[0])


def test_fifo_pick_matches_reference_scan():
    """fifo_pick_map (indexed) == reference scan on a head job with mixed
    localities, including the no-replica fallback to the head task."""
    for case in range(20):
        out = []
        for pick in (fifo_pick_map, reference_fifo_pick_map):
            cluster = VirtualCluster([2, 2])
            hosts = [h.hid for h in cluster.hosts()]
            q = TaskQueue("t", cluster)
            rng2 = random.Random(1000 + case)
            for j in range(2):
                for b in range(rng2.randint(2, 6)):
                    sid = f"f/{case}/{j}/{b}"
                    if rng2.random() < 0.8:
                        cluster.place_shard(
                            sid, rng2.sample(hosts, rng2.randint(1, 2)))
                    q.append(mk_map(j, b, sid))
            seq = []
            while q:
                hid = hosts[rng2.randrange(len(hosts))]
                t = pick(q, hid, cluster)
                seq.append((t.job_id, t.index))
            out.append(seq)
        assert out[0] == out[1]


# -------------------------------------------------------- TaskQueue units --
def test_tombstone_removal_is_lazy_and_consistent():
    q = TaskQueue("t")
    tasks = [mk_map(1, i, f"s{i}") for i in range(5)]
    q.extend(tasks)
    q.remove(tasks[2])
    q.remove(tasks[0])
    assert len(q) == 3
    assert list(q) == [tasks[1], tasks[3], tasks[4]]
    assert q.peek() is tasks[1]          # tombstoned head purged
    assert q.popleft() is tasks[1]
    with pytest.raises(ValueError):
        q.remove(tasks[2])               # double-remove
    assert [q.popleft() for _ in range(2)] == [tasks[3], tasks[4]]
    assert len(q) == 0 and not q
    with pytest.raises(IndexError):
        q.popleft()


def test_locality_index_updates():
    cluster = VirtualCluster([2, 2])
    h00, h01, h10 = HostId(0, 0), HostId(0, 1), HostId(1, 0)
    cluster.place_shard("a", [h00])
    cluster.place_shard("b", [h01, h10])
    q = TaskQueue("t", cluster)
    ta, tb, tc = mk_map(1, 0, "a"), mk_map(1, 1, "b"), mk_map(1, 2, "nowhere")
    q.extend([ta, tb, tc])
    # host index
    assert q.peek_local(1, h00) is ta
    assert q.peek_local(1, h10) is tb
    assert q.peek_local(1, HostId(1, 1)) is None
    # pod index (multi-replica shard appears once per pod)
    assert q.peek_pod(1, 0) is ta
    assert q.peek_pod(1, 1) is tb
    # removal through one access path is visible through all others
    assert q.pick_local(1, h00) is ta
    assert q.peek_pod(1, 0) is tb        # ta gone from the pod index too
    assert q.pick_pod(1, 1) is tb
    assert q.peek_local(1, h10) is None
    # no-replica task is only reachable as job head
    assert q.peek_job_head(1) is tc
    assert q.pick_job_head(1) is tc
    assert q.head_job() is None and len(q) == 0


def test_head_job_follows_fifo_order():
    q = TaskQueue("t")
    a = [mk_map(7, i, f"a{i}") for i in range(2)]
    b = [mk_map(8, i, f"b{i}") for i in range(2)]
    q.extend(a)
    q.extend(b)
    assert q.head_job() == 7
    q.remove(a[0])
    q.remove(a[1])
    assert q.head_job() == 8             # job 7 drained


def test_ready_reduce_transition():
    q = TaskQueue("t")
    r1 = [ReduceTask(1, i) for i in range(2)]
    r2 = [ReduceTask(2, i) for i in range(2)]
    q.extend(r1)
    q.extend(r2)
    never = lambda t: False
    # nothing ready: neither predicate nor marks yield a task
    assert q.pick_ready(never) is None
    assert q.pick_ready(never, trust_marks=True) is None
    # later job becomes ready first
    q.mark_job_ready(2)
    assert q.pick_ready(never) is r2[0]
    assert q.pick_ready(never, trust_marks=True) is r2[1]
    # then the earlier job: enqueue order among ready jobs is preserved
    q.mark_job_ready(1)
    assert q.pick_ready(never, trust_marks=True) is r1[0]
    # marking is idempotent and drained jobs purge from the ready heap
    q.mark_job_ready(1)
    assert q.pick_ready(never) is r1[1]
    assert q.pick_ready(never, trust_marks=True) is None
    assert len(q) == 0


def test_ready_predicate_without_marks():
    """Pure-predicate readiness (no notifications) follows queue order."""
    q = TaskQueue("t")
    r1, r2 = ReduceTask(1, 0), ReduceTask(2, 0)
    q.extend([r1, r2])
    assert q.pick_ready(lambda t: t.job_id == 2) is r2
    assert q.pick_ready(lambda t: True) is r1


def test_cached_load_counters():
    cluster = VirtualCluster([2, 2])
    queues = ClusterQueues(cluster)
    ms = [mk_map(1, i, f"x{i}") for i in range(4)]
    rs = [ReduceTask(1, 0)]
    queues.pods[0].mq0.extend(ms[:3])
    queues.pods[1].mq0.append(ms[3])
    queues.pods[1].rq0.extend(rs)
    assert queues.pods[0].unprocessed() == 3
    assert queues.pods[1].unprocessed() == 2
    assert queues.map_backlog.n == 4 and queues.red_backlog.n == 1
    assert queues.total_pending() == 5
    assert queues.least_loaded_pod() == 1
    queues.pods[0].mq0.remove(ms[1])
    queues.pods[0].mq0.popleft()
    assert queues.pods[0].unprocessed() == 1
    assert queues.map_backlog.n == 2
    assert queues.least_loaded_pod() == 0


def test_legacy_int_constructor_and_opaque_payloads():
    """ClusterQueues(int) + arbitrary objects (policy unit-test idiom)."""
    queues = ClusterQueues(3)
    queues.pods[0].mq0.extend([object()] * 5)
    queues.pods[1].mq0.extend([object()] * 2)
    assert queues.least_loaded_pod() == 2
    assert queues.pods[0].unprocessed() == 5
    assert queues.total_pending() == 7


# ------------------------------------------------------- dispatch backlog --
def test_dispatch_backlog_gating_matches_naive_polling_counts():
    """The backlog-gated dispatcher completes the same jobs as the seed-style
    poll-everything dispatcher (assignment order may differ: host shuffles
    draw from the same stream at different times)."""
    for poll_all in (False, True):
        cluster, jobs = random_cluster_and_jobs(17)
        algo = make_algorithm("joss-t", cluster)
        cfg = SimConfig(poll_all_hosts=poll_all)
        res = Simulator(cluster, algo, jobs, config=cfg, seed=3).run()
        assert len(res.job_finish) == len(jobs)
        for j in res.jobs:
            assert j.done()


def test_map_less_job_reduces_become_ready():
    """A job with zero map tasks must open its shuffle gate at submit."""
    cluster = VirtualCluster([2, 2])
    job = Job(name="r-only", code_key="r", input_type="web",
              shard_ids=[], shard_bytes=[], n_reducers=2)
    algo = make_algorithm("fifo", cluster)
    res = Simulator(cluster, algo, [job], seed=1).run()
    assert job.done()
    assert len(res.task_logs) == 2
