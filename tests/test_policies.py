"""§4.2 scheduling policies, including the paper's Fig. 3 worked example."""
from repro.core import (Job, VirtualCluster, policy_a, policy_b, policy_c)
from repro.core.queues import ClusterQueues
from repro.core.topology import HostId


def fig3_cluster_and_job():
    """Fig. 3 exactly as the paper's §4.2.2 walkthrough implies:

    cen1: {B1, B2, B4} | cen2: {B1, B2, B3, B5} | cen3: {B3, B4, B5, B6}
    (B6's two replicas both live inside cen3, on different VPSs.)
    """
    cluster = VirtualCluster([2, 2, 2])
    reps = {
        "B1": [(0, 0), (1, 0)], "B2": [(0, 0), (1, 1)],
        "B3": [(1, 0), (2, 0)], "B4": [(0, 1), (2, 0)],
        "B5": [(1, 1), (2, 1)], "B6": [(2, 0), (2, 1)],
    }
    for sid, hids in reps.items():
        cluster.place_shard(sid, [HostId(p, i) for p, i in hids])
    job = Job(name="Y", code_key="Y", input_type="web",
              shard_ids=["B1", "B2", "B3", "B4", "B5", "B6"],
              shard_bytes=[128.0] * 6, n_reducers=2)
    return cluster, job


def test_policy_b_matches_fig3():
    cluster, job = fig3_cluster_and_job()
    plan = policy_b(job, cluster, ClusterQueues(3))
    by_shard = dict(zip(job.shard_ids, plan.map_assignment))
    # paper: cen2 takes the largest unique set {B1,B2,B3,B5} first ...
    assert [by_shard[b] for b in ("B1", "B2", "B3", "B5")] == [1, 1, 1, 1]
    # ... then cen3 takes the remaining {B4, B6} (cen1 has only {B4} left)
    assert [by_shard[b] for b in ("B4", "B6")] == [2, 2]
    # all reduce tasks go to the pod with most unique blocks: cen2
    assert plan.reduce_pod == 1
    assert plan.policy == "B" and not plan.new_queues


def test_policy_a_least_loaded():
    cluster, job = fig3_cluster_and_job()
    queues = ClusterQueues(3)
    queues.pods[0].mq0.extend([object()] * 5)
    queues.pods[1].mq0.extend([object()] * 2)
    # pod 2 empty -> least loaded
    plan = policy_a(job, cluster, queues)
    assert set(plan.map_assignment) == {2}
    assert plan.reduce_pod == 2
    assert plan.policy == "A"


def test_policy_c_same_placement_new_queues():
    cluster, job = fig3_cluster_and_job()
    b = policy_b(job, cluster, ClusterQueues(3))
    c = policy_c(job, cluster, ClusterQueues(3))
    assert c.map_assignment == b.map_assignment
    assert c.reduce_pod == b.reduce_pod
    assert c.new_queues and not b.new_queues


def test_policy_b_replica_less_shard_falls_back():
    cluster = VirtualCluster([2, 2])
    cluster.place_shard("B0", [HostId(0, 0)])
    job = Job(name="z", code_key="z", input_type="web",
              shard_ids=["B0", "GONE"], shard_bytes=[128.0, 128.0])
    plan = policy_b(job, cluster, ClusterQueues(2))
    assert len(plan.map_assignment) == 2
    assert plan.map_assignment[0] == 0      # replica-backed
    assert plan.map_assignment[1] in (0, 1)  # fallback is deterministic
