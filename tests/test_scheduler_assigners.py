"""Fig. 4 task scheduler + Fig. 5/6 assigners: FIFO-first profiling path,
round-robin over queues (starvation avoidance), TTA vs JTA semantics."""
from repro.core import (FpRegistry, Job, JobKind, JossScheduler, JossT,
                        JossJ, TaskState, VirtualCluster)
from repro.core.topology import HostId


def cluster2(n=4):
    c = VirtualCluster([n, n])
    return c


def mk_job(cluster, m, fp, name, pod=0):
    sids = [f"{name}/B{i}" for i in range(m)]
    for i, s in enumerate(sids):
        cluster.place_shard(s, [HostId(pod, i % cluster.pods[pod].n_hosts)])
    return Job(name=name, code_key=name, input_type="web", shard_ids=sids,
               shard_bytes=[128.0] * m, true_fp=fp)


def test_unknown_jobs_go_to_fifo_queues():
    c = cluster2()
    sched = JossScheduler(c)
    j = mk_job(c, 3, 1.0, "new")
    rec = sched.submit(j)
    assert rec.kind is JobKind.UNKNOWN
    assert len(sched.queues.mq_fifo) == 3
    assert len(sched.queues.rq_fifo) == 1
    # after completion the FP is memoized and the next submit is planned
    sched.record_completion(j, 1.0)
    j2 = mk_job(c, 3, 1.0, "new")
    rec2 = sched.submit(j2)
    assert rec2.kind is JobKind.SMALL_MH
    assert rec2.plan is not None


def test_policy_c_creates_fresh_queues_and_rr_interleaves():
    """A large job must not starve later small jobs (policy C + RR)."""
    c = cluster2(4)  # N_avg = 4
    algo = JossT(c)
    algo.registry.record(mk_job(c, 1, 1.0, "big"), 1.0)
    algo.registry.record(mk_job(c, 1, 1.0, "small"), 1.0)
    big = mk_job(c, 12, 1.0, "big", pod=0)       # large: 12 > 4
    small = mk_job(c, 2, 1.0, "small", pod=0)    # small MH
    algo.submit(big)
    algo.submit(small)
    pq = algo.scheduler.queues.pods[0]
    assert len(pq.map_queues) >= 2          # fresh queue for the large job
    # pull 4 tasks from pod 0 host: RR must alternate big/small queues
    picked = [algo.next_map_task(HostId(0, 0)) for _ in range(4)]
    names = [p.job_id for p in picked if p is not None]
    assert big.job_id in names and small.job_id in names
    # small job's tasks are served before the big job drains
    first_small = names.index(small.job_id)
    assert first_small <= 2


def test_fifo_queue_served_first():
    c = cluster2()
    algo = JossT(c)
    known = mk_job(c, 2, 1.0, "known", pod=0)
    algo.registry.record(known, 1.0)
    algo.submit(known)
    unknown = mk_job(c, 2, 1.0, "unknown", pod=0)
    algo.submit(unknown)
    t = algo.next_map_task(HostId(0, 0))
    assert t.job_id == unknown.job_id  # MQ_FIFO first (Fig. 5 line 6)


def test_jta_prefers_local_then_defers():
    """JTA (Fig. 6) picks the host-local task of the head job even when it
    is not at the head of the queue; TTA takes the head."""
    c = cluster2(4)
    tta, jta = JossT(c), JossJ(c)
    for algo in (tta, jta):
        j = mk_job(c, 4, 1.0, f"job-{algo.name}", pod=0)
        algo.registry.record(j, 1.0)
        algo.submit(j)
        # host (0,2) holds shard B2 (placed round-robin i % 4)
        t = algo.next_map_task(HostId(0, 2))
        if algo.name == "joss-t":
            assert t is not None and t.index == 0     # head of queue
        else:
            assert t is not None and t.index == 2     # local pick


def test_reduce_task_gating():
    c = cluster2()
    algo = JossT(c)
    j = mk_job(c, 2, 3.0, "rh", pod=1)
    algo.registry.record(j, 3.0)
    algo.submit(j)
    # reduce not ready until all maps done
    ready_no = lambda t: False
    ready_yes = lambda t: True
    pod = algo.plan_of(j).reduce_pod
    assert algo.next_reduce_task(HostId(pod, 0), ready_no) is None
    assert algo.next_reduce_task(HostId(pod, 0), ready_yes) is not None
