"""Launch-path integration: a real dry-run cell (lower+compile on 512
placeholder devices) and the roofline pipeline, in a subprocess."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_cell_compiles(tmp_path):
    out_json = tmp_path / "cell.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # dryrun sets its own 512-device flag
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-3-2b", "--shape", "decode_32k",
         "--json", str(out_json)],
        env=env, capture_output=True, text=True, timeout=560, cwd=REPO)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    rows = json.loads(out_json.read_text())
    assert rows[0]["status"] == "OK"
    assert rows[0]["n_devices"] == 256
    assert rows[0]["dominant"] == "memory"   # decode = cache-read bound
    assert rows[0]["collective_bytes_per_dev"] > 0
    assert rows[0]["memory"]["per_device_total"] > 0


def test_dryrun_multipod_cell(tmp_path):
    out_json = tmp_path / "cell_mp.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-7b", "--shape", "long_500k", "--multi-pod",
         "--json", str(out_json)],
        env=env, capture_output=True, text=True, timeout=560, cwd=REPO)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    rows = json.loads(out_json.read_text())
    assert rows[0]["status"] == "OK"
    assert rows[0]["n_devices"] == 512
    assert rows[0]["mesh"] == "2x16x16"


def test_skip_cells_are_recorded():
    from repro.configs import ARCHS
    skips = [(a, s) for a, c in ARCHS.items() for s in c.skip_shapes]
    assert len(skips) == 8  # 8 full-attention archs skip long_500k
    assert all(s == "long_500k" for _, s in skips)
    assert all(ARCHS[a].skip_reason for a, _ in skips)
