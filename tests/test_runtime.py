"""Fault-tolerance runtime: failure detection, speculative launch policy,
elastic re-mesh planning."""
import pytest

from repro.core.topology import VirtualCluster
from repro.runtime import (HealthTracker, HostState, SpeculativeLauncher,
                           plan_elastic_remesh)


def test_health_state_machine():
    ht = HealthTracker(suspect_after=5, dead_after=10)
    ht.beat("h1", now=0.0)
    ht.beat("h2", now=0.0)
    assert ht.sweep(4.0) == []
    assert ht.state("h1") is HostState.HEALTHY
    ht.sweep(6.0)
    assert ht.state("h1") is HostState.SUSPECT
    ht.beat("h2", 6.0)
    dead = ht.sweep(11.0)
    assert dead == ["h1"]
    assert ht.state("h2") is HostState.SUSPECT
    assert ht.alive() == ["h2"]
    # recovery: a beat resurrects a suspect
    ht.beat("h2", 12.0)
    assert ht.state("h2") is HostState.HEALTHY


def test_speculative_launcher_policy():
    sp = SpeculativeLauncher(slack=2.0, min_samples=3, max_backups=1)
    for i in range(3):
        sp.task_started(f"t{i}", now=0.0)
        sp.task_finished(f"t{i}", now=10.0)
    sp.task_started("slow", now=100.0)
    assert sp.stragglers(now=115.0) == []      # 15 < 2 * median(10)
    assert sp.stragglers(now=125.0) == ["slow"]
    sp.backup_launched("slow")
    assert sp.stragglers(now=200.0) == []      # max_backups reached
    sp.task_finished("slow", now=205.0)
    assert sp.stragglers(now=300.0) == []


def test_elastic_plan_reassigns_orphans():
    cluster = VirtualCluster([4, 4, 4])
    shard_home = {f"s{i}": i % 3 for i in range(9)}
    plan = plan_elastic_remesh(cluster, [0, 2], shard_home,
                               model_parallel=4)
    # shards homed on dead pod 1 get survivors, balanced
    orphans = {s for s, h in shard_home.items() if h == 1}
    assert set(plan.orphan_reassignment) == orphans
    assert set(plan.orphan_reassignment.values()) <= {0, 2}
    assert plan.new_td == pytest.approx(2.0)   # k=2 -> td=2
    assert plan.new_n_avg == pytest.approx(4.0)
    assert plan.batch_scale == pytest.approx(2 / 3)


def test_elastic_single_pod_td_infinite():
    cluster = VirtualCluster([4, 4])
    plan = plan_elastic_remesh(cluster, [1], {}, model_parallel=2)
    assert plan.new_td == float("inf")  # k=1: everything is "MH"/local
