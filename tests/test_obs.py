"""PR 7 tentpole: the telemetry subsystem, trace export and scoreboard.

The load-bearing claim is *pure observation*: attaching telemetry (or
any hook-only subsystem) must leave every trajectory bit-identical —
held against all 25 committed golden hashes here. Around that: registry
unit tests (window bucketing, range proration), trace exporter units
(tracks, size cap, byte-stable JSONL), scoreboard reads, the
scoreboard-fed autoscaler equivalence, and the PR 7 metrics hardening
(``normalized_jtt`` guards, ``fabric_by_kind``).
"""
import json

import pytest

from repro.obs import (MetricRegistry, TelemetryConfig, TelemetrySubsystem,
                       TraceExporter, WindowSeries)
from repro.sim import golden
from repro.sim.engine import EventKernel, ProfilingKernel, Subsystem

GOLDEN = golden.load_golden()


# ------------------------------------------------------- golden identity --
class _HookRecorder(Subsystem):
    """Overrides *every* hook (so every dispatch list is non-empty) and
    does nothing that could perturb the run."""

    def __init__(self):
        self.counts = {}

    def _n(self, key):
        self.counts[key] = self.counts.get(key, 0) + 1

    def start(self, now):
        self._n("start")

    def on_host_added(self, hid, now):
        self._n("added")

    def on_host_lost(self, host, now):
        self._n("lost")

    def on_host_notice(self, hid, deadline, reason, now):
        self._n("notice")

    def on_host_survived(self, hid, now):
        self._n("survived")

    def on_task_start(self, log, now):
        self._n("task_start")

    def on_task_finish(self, log, now):
        self._n("task_finish")

    def on_job_submit(self, job, now):
        self._n("job_submit")

    def on_job_finish(self, job, now):
        self._n("job_finish")

    def on_tick(self, now):
        self._n("tick")


@pytest.mark.parametrize("algo,variant", golden.golden_cases(),
                         ids=[golden.case_key(a, v)
                              for a, v in golden.golden_cases()])
def test_observers_leave_golden_trajectories_bit_identical(algo, variant):
    """Telemetry on + a hook-only recorder attached: every one of the 25
    anchored runs still hashes to the committed golden — observation
    owns no event kinds, consumes no RNG, perturbs nothing."""
    rec = _HookRecorder()
    res = golden.run_case(algo, variant, telemetry=TelemetryConfig(),
                          subsystems=(rec,))
    assert golden.signature_hash(res) == \
        GOLDEN[golden.case_key(algo, variant)], \
        f"telemetry-on trajectory diverged from golden: {variant}/{algo}"
    # and the observers actually observed
    assert rec.counts["task_finish"] == len(res.task_logs)
    assert rec.counts["job_submit"] == rec.counts["job_finish"] == 12
    tel = res.telemetry
    assert tel.registry.counter("jobs.finished").value == 12
    assert tel.registry.counter("tasks.started").value > 0
    assert len(tel.trace) > 0


# ---------------------------------------------------------- registry units --
def test_window_series_point_bucketing():
    s = WindowSeries("x", 10.0)
    s.add(0.0, 1.0)
    s.add(9.999, 2.0)
    s.add(10.0, 5.0)
    s.add(35.0, 7.0)
    assert s.values == [3.0, 5.0, 0.0, 7.0]
    assert s.at(1) == 5.0 and s.at(2) == 0.0 and s.at(99) == 0.0


def test_window_series_range_proration():
    s = WindowSeries("x", 10.0)
    # 30 MB uniformly over [5, 35): 5s + 10s + 10s + 5s of a 1 MB/s rate
    s.add_range(5.0, 35.0, 30.0)
    assert s.values == pytest.approx([5.0, 10.0, 10.0, 5.0])
    # inside a single window: the whole amount lands there
    s2 = WindowSeries("y", 10.0)
    s2.add_range(12.0, 17.0, 4.0)
    assert s2.values == pytest.approx([0.0, 4.0])
    # zero-length range degenerates to a point add
    s2.add_range(12.0, 12.0, 1.0)
    assert s2.values[1] == pytest.approx(5.0)


def test_window_series_boundary_exact():
    """A range ending exactly on a window edge must not spill a zero
    bucket past the edge."""
    s = WindowSeries("x", 10.0)
    s.add_range(5.0, 20.0, 15.0)
    assert s.values == pytest.approx([5.0, 10.0])


def test_window_series_closed_reads():
    s = WindowSeries("x", 10.0)
    s.add(5.0, 3.0)
    s.add(15.0, 4.0)
    # at t=17 the window [10,20) is still accumulating
    assert s.latest_closed(17.0) == 3.0
    assert s.closed_values(17.0) == [3.0]
    assert s.latest_closed(25.0) == 4.0
    # closed_values pads never-touched windows with zeros
    assert s.closed_values(45.0) == [3.0, 4.0, 0.0, 0.0]
    assert s.latest_closed(5.0) == 0.0   # nothing closed yet


def test_window_series_rejects_bad_width():
    with pytest.raises(ValueError):
        WindowSeries("x", 0.0)


def test_registry_get_or_create():
    reg = MetricRegistry(window=7.0)
    c = reg.counter("a")
    c.inc()
    c.inc(2.5)
    assert reg.counter("a") is c and c.value == 3.5
    g = reg.gauge("b")
    g.set(9)
    assert reg.gauge("b").value == 9
    s = reg.get_series("c")
    assert s.window == 7.0
    assert reg.get_series("d", window=2.0).window == 2.0
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3.5}
    assert snap["gauges"] == {"b": 9}
    assert set(snap["series"]) == {"c", "d"}


# ------------------------------------------------------------- trace units --
def test_trace_tracks_and_chrome_document():
    t = TraceExporter()
    t.complete("pod0", "host 0.0", "map:wc", 1.0, 2.5, args={"job": 0})
    t.complete("pod0", "host 0.1", "map:wc", 1.0, 3.0)
    t.instant("fleet", "churn", "host_lost", 4.0)
    doc = t.chrome_trace()
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    # 2 processes + 3 threads named
    assert len([m for m in meta if m["name"] == "process_name"]) == 2
    assert len([m for m in meta if m["name"] == "thread_name"]) == 3
    slices = [e for e in evs if e["ph"] == "X"]
    assert slices[0]["ts"] == 1_000_000 and slices[0]["dur"] == 1_500_000
    # same process, distinct threads
    assert slices[0]["pid"] == slices[1]["pid"]
    assert slices[0]["tid"] != slices[1]["tid"]
    json.dumps(doc)   # must be serializable as-is


def test_trace_size_cap_counts_drops():
    t = TraceExporter(limit=2)
    for i in range(5):
        t.instant("p", "t", f"e{i}", float(i))
    assert len(t) == 2 and t.dropped == 3
    # the JSONL keeps only the retained events
    assert t.jsonl().count("\n") == 2


def test_trace_jsonl_byte_stable():
    def build():
        t = TraceExporter()
        t.complete("pod0", "host 0.0", "map", 0.5, 1.5, args={"mb": 3.0})
        t.instant("fleet", "jobs", "submit", 0.0, args={"job": 1})
        return t
    a, b = build(), build()
    assert a.jsonl() == b.jsonl()
    assert a.sha256() == b.sha256()
    # every line is standalone JSON with sorted keys
    for line in a.jsonl().splitlines():
        obj = json.loads(line)
        assert list(obj) == sorted(obj)


# ------------------------------------------------- end-to-end observation --
def _elastic_run(telemetry, scaler=None, *, n_jobs=24, fabric=True,
                 seed=7):
    from repro.core.joss import make_algorithm
    from repro.elastic import (BacklogThresholdScaler, ChurnConfig,
                               ElasticEngine)
    from repro.sim.cluster_sim import FabricConfig, SimConfig, Simulator
    from repro.sim.workloads import (fabric_links, make_cluster,
                                     small_workload)
    hpp = (4, 4)
    cluster = make_cluster(hpp, map_slots=2)
    jobs = small_workload(cluster, seed=seed, n_jobs=n_jobs)
    algo = make_algorithm("joss-t", cluster)
    cfg = SimConfig(fabric=(FabricConfig(links=fabric_links(hpp))
                            if fabric else None),
                    telemetry=telemetry)
    eng = ElasticEngine(
        cluster,
        churn=ChurnConfig(seed=5, fail_rate=0.5, rejoin_delay=90.0),
        autoscaler=scaler or BacklogThresholdScaler(min_hosts=4))
    return Simulator(cluster, algo, jobs, config=cfg, seed=seed,
                     elastic=eng).run()


def test_scoreboard_fed_scaler_decisions_bit_identical():
    """The equivalence claim: a ``BacklogThresholdScaler`` reading
    backlog off the scoreboard (telemetry on auto-attaches it) makes the
    exact decisions of one reading the observation directly."""
    off = _elastic_run(None)
    on = _elastic_run(TelemetryConfig())
    assert golden.full_signature(off) == golden.full_signature(on)
    assert (off.n_host_adds, off.n_host_losses, off.cost_dollars) == \
        (on.n_host_adds, on.n_host_losses, on.cost_dollars)
    # the scoreboard really was attached and consulted
    tel = on.telemetry
    assert tel.registry.gauges["fleet.n_hosts"].value > 0


def test_link_series_cover_every_link_and_wan():
    res = _elastic_run(TelemetryConfig(window=20.0))
    sb = res.telemetry.scoreboard
    assert sorted(sb.link_names()) == ["down0", "down1", "up0", "up1",
                                      "wan"]
    horizon = res.wtt + 100.0
    for ln in sb.link_names():
        series = sb.link_util_series(ln, horizon)
        assert series, f"no utilization windows for {ln}"
        assert all(v >= 0.0 for v in series)
    # total windowed MB ~ the fabric's own accounting
    total = sum(sum(sb.series_values(f"link.{ln}.mb", horizon))
                for ln in sb.link_names())
    assert total > 0.0
    # per-kind stall series exist for the kinds the fabric reported
    for kind, agg in res.fabric.by_kind.items():
        if agg[2] > 0.0:
            assert sum(sb.series_values(f"stall.{kind}", horizon)) > 0.0


def test_scoreboard_reads_and_ewma():
    res = _elastic_run(TelemetryConfig(window=20.0, ewma_alpha=0.5))
    sb = res.telemetry.scoreboard
    assert sb.window == 20.0
    assert sb.counter("jobs.finished") == 24.0
    assert sb.counter("no.such.counter") == 0.0
    assert sb.gauge("no.such.gauge", -1) == -1
    assert sb.latest("no.such.series", 100.0) == 0.0
    vals = sb.series_values("backlog.map", res.wtt + 100.0)
    assert vals
    # EWMA recurrence on the closed values
    acc = vals[0]
    for v in vals[1:]:
        acc = 0.5 * v + 0.5 * acc
    assert sb.ewma("backlog.map", res.wtt + 100.0) == pytest.approx(acc)
    mf, rf = sb.job_progress(res.jobs[0].job_id)
    assert mf == 1.0 and rf == 1.0


def test_trace_deterministic_per_seed_across_runs():
    """Two telemetry-on runs of the same seed — in the *same* process,
    where the global job counter differs — produce byte-identical
    JSONL (ids are remapped to submission order)."""
    a = _elastic_run(TelemetryConfig())
    b = _elastic_run(TelemetryConfig())
    assert a.telemetry.trace.jsonl() == b.telemetry.trace.jsonl()
    assert a.telemetry.trace.sha256() == b.telemetry.trace.sha256()


def test_trace_cap_applies_end_to_end():
    res = _elastic_run(TelemetryConfig(trace_limit=50))
    tr = res.telemetry.trace
    assert len(tr) == 50 and tr.dropped > 0
    # and tracing can be disabled outright while metrics keep flowing
    res2 = _elastic_run(TelemetryConfig(trace=False))
    assert res2.telemetry.trace is None
    assert res2.telemetry.registry.counter("jobs.finished").value == 24.0


def test_telemetry_off_is_truly_off():
    res = _elastic_run(None)
    assert res.telemetry is None


# --------------------------------------------------------- kernel profiling --
def test_profiling_kernel_counts_every_kind():
    from repro.core.joss import make_algorithm
    from repro.sim.cluster_sim import Simulator
    from repro.sim.workloads import make_cluster, small_workload
    cluster = make_cluster((2, 2))
    jobs = small_workload(cluster, seed=3, n_jobs=3)
    sim = Simulator(cluster, make_algorithm("fifo", cluster), jobs,
                    seed=3)
    sim._make_kernel = lambda: ProfilingKernel()
    res = sim.run()
    k = sim.kernel
    assert isinstance(k, ProfilingKernel)
    assert k.kind_n["submit"] == 3
    n_tasks = sum(j.m + len(j.reduce_tasks) for j in jobs)
    assert k.kind_n["map_done"] + k.kind_n["reduce_done"] == n_tasks
    assert all(s >= 0.0 for s in k.kind_s.values())
    assert set(k.kind_s) == set(k.kind_n)
    assert len(res.job_finish) == 3


def test_profiling_kernel_matches_plain_kernel_trajectory():
    from repro.core.joss import make_algorithm
    from repro.sim.cluster_sim import Simulator
    from repro.sim.workloads import make_cluster, small_workload

    def run(profiled):
        cluster = make_cluster((2, 2))
        jobs = small_workload(cluster, seed=3, n_jobs=3)
        sim = Simulator(cluster, make_algorithm("fifo", cluster), jobs,
                        seed=3)
        if profiled:
            sim._make_kernel = lambda: ProfilingKernel()
        return sim.run()

    assert golden.full_signature(run(False)) == \
        golden.full_signature(run(True))


# ------------------------------------------------------- metrics hardening --
def _empty_result():
    from repro.sim.cluster_sim import SimResult
    return SimResult(algorithm="fifo", task_logs=[], job_submit={},
                     job_finish={}, int_bytes=0.0, pod_bytes=0.0,
                     wtt=0.0, jobs=[])


def test_summarize_empty_run():
    from repro.sim.metrics import summarize
    s = summarize(_empty_result())
    assert s.avg_jtt == {} and s.map_locality == {}
    assert s.vps_load_mean == 0.0 and s.vps_load_std == 0.0
    assert s.completion_curve == []
    assert s.reexec_map_locality is None
    assert s.fabric_by_kind == {}


def test_summarize_zero_finished_jobs_named_benchmark():
    from repro.sim.metrics import summarize
    s = summarize(_empty_result(), benchmarks=["wordcount"])
    assert s.avg_jtt == {"wordcount": 0.0}
    assert s.reduce_locality == {"wordcount": 1.0}
    loc = s.map_locality["wordcount"]
    assert (loc.vps, loc.cen, loc.off_cen) == (0.0, 0.0, 0.0)


def test_normalized_jtt_guards():
    from repro.sim.metrics import normalized_jtt, summarize
    assert normalized_jtt([]) == {}
    a = summarize(_empty_result(), benchmarks=["wc"])
    a.algorithm = "fifo"
    a.avg_jtt = {"wc": 10.0}
    b = summarize(_empty_result(), benchmarks=["wc"])
    b.algorithm = "fair"
    b.avg_jtt = {"wc": 20.0}
    # missing reference: falls back to the first summary, no StopIteration
    out = normalized_jtt([a, b], reference="joss-t")
    assert out["fifo"]["wc"] == 1.0 and out["fair"]["wc"] == 2.0
    # zero-JTT reference benchmark yields 0.0, not ZeroDivisionError
    a.avg_jtt = {"wc": 0.0}
    out = normalized_jtt([a, b], reference="fifo")
    assert out["fair"]["wc"] == 0.0


def test_fabric_by_kind_surfaced_in_summary():
    from repro.sim.metrics import summarize
    res = _elastic_run(None)
    s = summarize(res)
    assert s.fabric_by_kind
    assert set(s.fabric_by_kind) == set(res.fabric.by_kind)
    for kind, (n, mb, stall) in s.fabric_by_kind.items():
        ref = res.fabric.by_kind[kind]
        assert (n, mb, stall) == (ref[0], ref[1], ref[2])
        assert isinstance(n, int)
    # flow counts add up
    assert sum(v[0] for v in s.fabric_by_kind.values()) == \
        res.fabric.n_flows


# ------------------------------------------------------------- misc seams --
def test_telemetry_subsystem_registers_no_event_kinds():
    from repro.sim.workloads import make_cluster

    class _Sim:
        fabric = None

        def __init__(self):
            self.cluster = make_cluster((2, 2))
            self.jobs = []

    k = EventKernel()
    before = set(k._handlers)
    tel = TelemetrySubsystem()
    tel.attach(_Sim(), k)
    tel.start(0.0)
    assert set(k._handlers) == before
    assert len(k) == 0          # and pushed nothing onto the heap
