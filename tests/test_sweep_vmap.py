"""Equivalence of the batched ``jax.vmap`` progressive-fill kernel with
the scalar allocator (PR 8 satellite): the pure-Python reference must be
**bit-identical** to the rates the live allocator recorded, the batched
kernel bit-close (``RTOL``) with identical completion orderings, and
padding must never let one problem leak into another. All jax-dependent
tests skip cleanly when jax is unavailable."""
import numpy as np
import pytest

from repro.sweep import vmap_fill as vf

needs_jax = pytest.mark.skipif(not vf.HAVE_JAX,
                               reason="jax unavailable")


@pytest.fixture(scope="module")
def corpus():
    """Real fill problems captured from one contended cell."""
    snaps = vf.contention_snapshots("joss-t", "oversub8", limit=80)
    assert len(snaps) >= 20, "capture seam produced too few problems"
    return snaps


# ------------------------------------------------- scalar reference --
def test_reference_bit_identical_to_live_allocator(corpus):
    for snap in corpus:
        ref = vf.fill_reference(snap)
        recorded = [c["rate"] for c in snap["classes"]]
        assert ref["rates"] == recorded      # bit-identical floats
        if snap["dt_next"] is None:
            assert ref["dt_next"] is None
        else:
            assert ref["dt_next"] == pytest.approx(snap["dt_next"],
                                                   rel=1e-12)


def test_reference_even_split_on_one_link():
    snap = {"links": [["wan", 0, 10.0]],
            "classes": [{"path": [["wan", 0]], "cap": 100.0, "n": 1,
                         "vdone": 0.0, "target": 5.0},
                        {"path": [["wan", 0]], "cap": 100.0, "n": 1,
                         "vdone": 2.5, "target": 5.0}]}
    ref = vf.fill_reference(snap)
    assert ref["rates"] == [5.0, 5.0]
    assert ref["dt_next"] == 0.5             # (5 - 2.5) / 5


def test_reference_class_cap_beats_link_share():
    snap = {"links": [["wan", 0, 10.0]],
            "classes": [{"path": [["wan", 0]], "cap": 2.0, "n": 1,
                         "vdone": 0.0, "target": 4.0},
                        {"path": [["wan", 0]], "cap": 100.0, "n": 1,
                         "vdone": 0.0, "target": None}]}
    ref = vf.fill_reference(snap)
    # the capped class fixes at 2; the survivor takes the remaining 8
    assert ref["rates"] == [2.0, 8.0]
    assert ref["dt_next"] == 2.0             # only the finite target


# ---------------------------------------------------- batched kernel --
@needs_jax
def test_batched_fill_bit_close_with_identical_orderings(corpus):
    batch = vf.batched_fill(corpus)
    ref = vf.batched_fill_reference(corpus)
    assert batch["rates"].shape == ref["rates"].shape
    assert np.allclose(batch["rates"], ref["rates"], rtol=vf.RTOL,
                       atol=0.0)
    assert np.allclose(batch["dt_next"], ref["dt_next"], rtol=vf.RTOL,
                       equal_nan=True)
    for i in range(len(corpus)):
        assert vf.orderings_match(ref["etas"][i], batch["etas"][i])


@needs_jax
def test_padding_never_leaks_across_problems(corpus):
    """Mixed-shape batches pad every problem to the widest (C, L); a
    problem's row must not depend on what it is batched with."""
    sizes = {len(s["classes"]) for s in corpus}
    assert len(sizes) > 1, "corpus is uniform; padding untested"
    full = vf.batched_fill(corpus)
    for i in (0, len(corpus) // 2, len(corpus) - 1):
        alone = vf.batched_fill([corpus[i]])
        c = len(corpus[i]["classes"])
        assert np.allclose(alone["rates"][0, :c], full["rates"][i, :c],
                           rtol=vf.RTOL, atol=0.0)
        assert np.allclose(alone["dt_next"][0], full["dt_next"][i],
                           rtol=vf.RTOL, equal_nan=True)


@needs_jax
def test_padded_lanes_stay_inert(corpus):
    batch = vf.batched_fill(corpus)
    for i, snap in enumerate(corpus):
        c = len(snap["classes"])
        assert np.all(batch["rates"][i, c:] == 0.0)
        assert np.all(np.isinf(batch["etas"][i, c:]))


def test_batched_reference_matches_scalar(corpus):
    ref = vf.batched_fill_reference(corpus)
    for i, snap in enumerate(corpus):
        one = vf.fill_reference(snap)
        c = len(snap["classes"])
        assert list(ref["rates"][i, :c]) == one["rates"]


# ----------------------------------------- degenerate packed inputs --
def test_packed_zero_class_snapshot_is_all_padding():
    """A snapshot with links but no classes (an idle fabric) packs to
    the floor shape with every lane inert: n=0, inf caps, no members."""
    snap = {"links": [["wan", 0, 10.0]], "classes": []}
    p = vf.PackedProblems([snap])
    assert p.n_classes == 1 and p.n_links == 1
    assert np.all(p.n == 0.0)
    assert np.all(np.isinf(p.fcap)) and np.all(np.isinf(p.target))
    assert np.all(p.members == 0.0)
    assert vf.fill_reference(snap) == {"rates": [], "etas": [],
                                       "dt_next": None}


def test_packed_empty_batch_has_floor_shapes():
    p = vf.PackedProblems([])
    assert p.caps.shape == (0, 1) and p.n.shape == (0, 1)
    assert p.members.shape == (0, 1, 1)


@needs_jax
def test_batched_fill_zero_class_snapshot_resolves_inert():
    out = vf.batched_fill([{"links": [["wan", 0, 10.0]],
                            "classes": []}])
    assert np.all(out["rates"] == 0.0)
    assert np.all(np.isinf(out["etas"]))
    assert np.all(np.isinf(out["dt_next"]))


def _single_flow_snap():
    # one class, one member flow, crossing one link: rate is the
    # whole link (cap doesn't bind), eta = (target - vdone) / rate
    return {"links": [["wan", 0, 6.0]],
            "classes": [{"path": [["wan", 0]], "cap": 100.0, "n": 1,
                         "vdone": 1.0, "target": 4.0}]}


def _all_capped_snap():
    # every class's own cap undercuts its link share: the fill fixes
    # all of them at cap and the link is left slack
    return {"links": [["wan", 0, 100.0]],
            "classes": [{"path": [["wan", 0]], "cap": 2.0, "n": 2,
                         "vdone": 0.0, "target": 8.0},
                        {"path": [["wan", 0]], "cap": 3.0, "n": 1,
                         "vdone": 1.0, "target": None}]}


def test_reference_single_flow_class():
    ref = vf.fill_reference(_single_flow_snap())
    assert ref["rates"] == [6.0]
    assert ref["dt_next"] == 0.5              # (4 - 1) / 6


def test_reference_all_capped_classes():
    ref = vf.fill_reference(_all_capped_snap())
    assert ref["rates"] == [2.0, 3.0]
    assert ref["dt_next"] == 4.0              # (8 - 0) / 2


@needs_jax
def test_batched_fill_degenerate_snapshots_match_reference():
    """Zero-class, single-flow and all-capped problems through one
    mixed batch: each row bit-close to its scalar reference, the empty
    row fully inert."""
    snaps = [{"links": [["wan", 0, 10.0]], "classes": []},
             _single_flow_snap(), _all_capped_snap()]
    out = vf.batched_fill(snaps)
    refb = vf.batched_fill_reference(snaps)
    assert np.allclose(out["rates"], refb["rates"], rtol=vf.RTOL,
                       atol=0.0)
    assert np.allclose(out["dt_next"], refb["dt_next"], rtol=vf.RTOL,
                       equal_nan=True)
    assert np.all(out["rates"][0] == 0.0)


# ------------------------------------------------------ live solver --
def _problem(snapshot):
    """A ``fill_problem()``-shaped dict from a snapshot (same packing
    the fabric does, including ``remaining = target - vdone``)."""
    p = vf.PackedProblems([snapshot])
    C = max(1, len(snapshot["classes"]))
    L = max(1, len(snapshot["links"]))
    return {"caps": p.caps[0, :L], "members": p.members[0, :C, :L],
            "n": p.n[0, :C], "fcap": p.fcap[0, :C],
            "cap_rank": p.cap_rank[0, :C],
            "remaining": p.target[0, :C] - p.vdone[0, :C]}


@needs_jax
def test_solver_matches_reference_on_corpus(corpus):
    with vf.BatchedFillSolver() as solver:
        sols = solver.solve([_problem(s) for s in corpus])
    assert len(sols) == len(corpus)
    for snap, (rates, dt) in zip(corpus, sols):
        ref = vf.fill_reference(snap)
        c = len(snap["classes"])
        assert rates.shape == (max(1, c),)
        assert np.allclose(rates[:c], ref["rates"], rtol=vf.RTOL,
                           atol=0.0)
        if ref["dt_next"] is None:
            assert np.isinf(dt)
        else:
            assert dt == pytest.approx(ref["dt_next"], rel=vf.RTOL)


@needs_jax
def test_solver_results_independent_of_batch_composition(corpus):
    """The solver's padding-inertness claim is *bit*-exact: a problem
    solved alone, in a small batch, or in the full epoch batch returns
    identical bytes — batch composition can never perturb a lane."""
    probs = [_problem(s) for s in corpus]
    with vf.BatchedFillSolver() as solver:
        full = solver.solve(probs)
        for i in (0, len(probs) // 2, len(probs) - 1):
            alone = solver.solve([probs[i]])[0]
            assert np.array_equal(alone[0], full[i][0])
            assert (alone[1] == full[i][1]
                    or (np.isinf(alone[1]) and np.isinf(full[i][1])))
        assert solver.n_batches == 4 and solver.n_problems > len(probs)


@needs_jax
def test_solver_degenerate_problems():
    """Zero-class / single-flow / all-capped problems through the live
    solver in one batch."""
    empty = {"links": [["wan", 0, 10.0]], "classes": []}
    snaps = [empty, _single_flow_snap(), _all_capped_snap()]
    with vf.BatchedFillSolver() as solver:
        sols = solver.solve([_problem(s) for s in snaps])
        assert solver.solve([]) == []
    (r0, dt0), (r1, dt1), (r2, dt2) = sols
    assert np.all(r0 == 0.0) and np.isinf(dt0)   # padding lane only
    assert list(r1) == [6.0] and dt1 == 0.5
    assert list(r2) == [2.0, 3.0] and dt2 == 4.0


# --------------------------------------------------- ordering helper --
def test_orderings_match_tolerates_ulp_ties_only():
    a = np.array([1.0, 2.0, 3.0, np.inf])
    assert vf.orderings_match(a, a)
    ulp = np.array([1.0, 2.0 * (1 + 1e-12), 3.0, np.inf])
    assert vf.orderings_match(a, ulp)
    swapped = np.array([2.0, 1.0, 3.0, np.inf])    # real reorder
    assert not vf.orderings_match(a, swapped)
    near_tie = np.array([1.0, 1.0 + 1e-12, 3.0, np.inf])
    tie_swap = np.array([1.0 + 1e-12, 1.0, 3.0, np.inf])
    assert vf.orderings_match(near_tie, tie_swap)
    finite_drift = np.array([1.0, 2.0, 3.0, 4.0])  # inf became finite
    assert not vf.orderings_match(a, finite_drift)
