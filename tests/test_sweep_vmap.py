"""Equivalence of the batched ``jax.vmap`` progressive-fill kernel with
the scalar allocator (PR 8 satellite): the pure-Python reference must be
**bit-identical** to the rates the live allocator recorded, the batched
kernel bit-close (``RTOL``) with identical completion orderings, and
padding must never let one problem leak into another. All jax-dependent
tests skip cleanly when jax is unavailable."""
import numpy as np
import pytest

from repro.sweep import vmap_fill as vf

needs_jax = pytest.mark.skipif(not vf.HAVE_JAX,
                               reason="jax unavailable")


@pytest.fixture(scope="module")
def corpus():
    """Real fill problems captured from one contended cell."""
    snaps = vf.contention_snapshots("joss-t", "oversub8", limit=80)
    assert len(snaps) >= 20, "capture seam produced too few problems"
    return snaps


# ------------------------------------------------- scalar reference --
def test_reference_bit_identical_to_live_allocator(corpus):
    for snap in corpus:
        ref = vf.fill_reference(snap)
        recorded = [c["rate"] for c in snap["classes"]]
        assert ref["rates"] == recorded      # bit-identical floats
        if snap["dt_next"] is None:
            assert ref["dt_next"] is None
        else:
            assert ref["dt_next"] == pytest.approx(snap["dt_next"],
                                                   rel=1e-12)


def test_reference_even_split_on_one_link():
    snap = {"links": [["wan", 0, 10.0]],
            "classes": [{"path": [["wan", 0]], "cap": 100.0, "n": 1,
                         "vdone": 0.0, "target": 5.0},
                        {"path": [["wan", 0]], "cap": 100.0, "n": 1,
                         "vdone": 2.5, "target": 5.0}]}
    ref = vf.fill_reference(snap)
    assert ref["rates"] == [5.0, 5.0]
    assert ref["dt_next"] == 0.5             # (5 - 2.5) / 5


def test_reference_class_cap_beats_link_share():
    snap = {"links": [["wan", 0, 10.0]],
            "classes": [{"path": [["wan", 0]], "cap": 2.0, "n": 1,
                         "vdone": 0.0, "target": 4.0},
                        {"path": [["wan", 0]], "cap": 100.0, "n": 1,
                         "vdone": 0.0, "target": None}]}
    ref = vf.fill_reference(snap)
    # the capped class fixes at 2; the survivor takes the remaining 8
    assert ref["rates"] == [2.0, 8.0]
    assert ref["dt_next"] == 2.0             # only the finite target


# ---------------------------------------------------- batched kernel --
@needs_jax
def test_batched_fill_bit_close_with_identical_orderings(corpus):
    batch = vf.batched_fill(corpus)
    ref = vf.batched_fill_reference(corpus)
    assert batch["rates"].shape == ref["rates"].shape
    assert np.allclose(batch["rates"], ref["rates"], rtol=vf.RTOL,
                       atol=0.0)
    assert np.allclose(batch["dt_next"], ref["dt_next"], rtol=vf.RTOL,
                       equal_nan=True)
    for i in range(len(corpus)):
        assert vf.orderings_match(ref["etas"][i], batch["etas"][i])


@needs_jax
def test_padding_never_leaks_across_problems(corpus):
    """Mixed-shape batches pad every problem to the widest (C, L); a
    problem's row must not depend on what it is batched with."""
    sizes = {len(s["classes"]) for s in corpus}
    assert len(sizes) > 1, "corpus is uniform; padding untested"
    full = vf.batched_fill(corpus)
    for i in (0, len(corpus) // 2, len(corpus) - 1):
        alone = vf.batched_fill([corpus[i]])
        c = len(corpus[i]["classes"])
        assert np.allclose(alone["rates"][0, :c], full["rates"][i, :c],
                           rtol=vf.RTOL, atol=0.0)
        assert np.allclose(alone["dt_next"][0], full["dt_next"][i],
                           rtol=vf.RTOL, equal_nan=True)


@needs_jax
def test_padded_lanes_stay_inert(corpus):
    batch = vf.batched_fill(corpus)
    for i, snap in enumerate(corpus):
        c = len(snap["classes"])
        assert np.all(batch["rates"][i, c:] == 0.0)
        assert np.all(np.isinf(batch["etas"][i, c:]))


def test_batched_reference_matches_scalar(corpus):
    ref = vf.batched_fill_reference(corpus)
    for i, snap in enumerate(corpus):
        one = vf.fill_reference(snap)
        c = len(snap["classes"])
        assert list(ref["rates"][i, :c]) == one["rates"]


# --------------------------------------------------- ordering helper --
def test_orderings_match_tolerates_ulp_ties_only():
    a = np.array([1.0, 2.0, 3.0, np.inf])
    assert vf.orderings_match(a, a)
    ulp = np.array([1.0, 2.0 * (1 + 1e-12), 3.0, np.inf])
    assert vf.orderings_match(a, ulp)
    swapped = np.array([2.0, 1.0, 3.0, np.inf])    # real reorder
    assert not vf.orderings_match(a, swapped)
    near_tie = np.array([1.0, 1.0 + 1e-12, 3.0, np.inf])
    tie_swap = np.array([1.0 + 1e-12, 1.0, 3.0, np.inf])
    assert vf.orderings_match(near_tie, tie_swap)
    finite_drift = np.array([1.0, 2.0, 3.0, 4.0])  # inf became finite
    assert not vf.orderings_match(a, finite_drift)
