"""Sweep-engine robustness (PR 10 satellites): worker-crash recovery
with pool rebuilds, per-cell wall-clock timeouts, poisoned cells after
``max_attempts``, and the chaos cell family.

The ``selftest`` cell family crashes (``os._exit``) or hangs worker
processes *on purpose* — every engine here runs with ``workers >= 2``
so the sabotage lands in a spawned pool worker, never in the pytest
process. The headline claim is the acceptance criterion from the
issue: a sweep with an injected worker crash and a hung cell completes,
and its aggregates are byte-identical (for the unaffected cells) to a
crash-free run.
"""
import pytest

from repro.sweep import (CellSpec, SweepEngine, aggregate_json,
                         make_params, run_cell)

FAST = dict(retry_backoff_s=0.05, retry_backoff_cap_s=0.2)


def _ok_cells(n=4):
    return [CellSpec("selftest", "a", "ok", i) for i in range(n)]


# ------------------------------------------------------- crash recovery --
def test_worker_crash_is_retried_and_sweep_completes(tmp_path):
    """A hard worker crash (BrokenProcessPool) poisons nothing on the
    first strike: the pool is rebuilt, the cell retried, and every cell
    — including the crasher — delivers a result."""
    ok = _ok_cells()
    crash = [CellSpec("selftest", "a", "crash_once", 0,
                      make_params(flag_dir=str(tmp_path)))]
    eng = SweepEngine(workers=2, **FAST)
    res, stats = eng.run(ok + crash)
    assert len(res) == 5
    assert stats.n_pool_rebuilds >= 1
    assert stats.n_retried >= 1
    assert stats.n_poisoned == 0
    row = stats.cell_report[crash[0].key()]
    assert row["status"] == "ok" and row["crashes"] >= 1


def test_crash_leaves_unaffected_aggregates_byte_identical(tmp_path):
    """The acceptance criterion: aggregates of the cells untouched by
    the crash are byte-identical to a crash-free run's."""
    ok = _ok_cells()
    crash = [CellSpec("selftest", "a", "crash_once", 0,
                      make_params(flag_dir=str(tmp_path)))]
    noisy, _ = SweepEngine(workers=2, **FAST).run(ok + crash)
    clean, _ = SweepEngine(workers=2, **FAST).run(ok)
    unaffected = {k: v for k, v in noisy.items() if k in clean}
    assert aggregate_json(unaffected, metrics=("ok",)) \
        == aggregate_json(clean, metrics=("ok",))


# ------------------------------------------------------- hung cells -------
def test_hung_cell_times_out_and_retries(tmp_path):
    """A cell that outlives ``cell_timeout`` is reclaimed (the only way
    to kill a hung spawn worker is killing the pool), charged a timeout,
    and retried to completion."""
    ok = _ok_cells()
    hang = [CellSpec("selftest", "a", "hang_once", 0,
                     make_params(flag_dir=str(tmp_path), hang_s=600.0))]
    # generous timeout: it must absorb spawn-worker boot (~seconds under
    # load) so only the genuine hang trips it
    eng = SweepEngine(workers=2, cell_timeout=15.0, **FAST)
    res, stats = eng.run(ok + hang)
    assert len(res) == 5
    assert stats.n_timeouts == 1
    assert stats.n_poisoned == 0
    row = stats.cell_report[hang[0].key()]
    assert row["status"] == "ok" and row["timeouts"] == 1


# ------------------------------------------------------ poisoned cells ----
def test_always_crashing_cell_is_poisoned_not_fatal():
    """After ``max_attempts`` crashes the cell is poisoned: absent from
    the results, present in the report, and run() returns instead of
    raising. (The cell runs alone: a broken pool cannot attribute the
    crash, so innocent in-flight cells are charged too — co-scheduling
    an always-crasher with tight ``max_attempts`` would poison
    bystanders by design.)"""
    poison = [CellSpec("selftest", "a", "crash_always", 0)]
    eng = SweepEngine(workers=2, max_attempts=2, **FAST)
    res, stats = eng.run(poison)
    assert stats.n_poisoned == 1
    assert stats.n_pool_rebuilds == 2
    row = stats.cell_report[poison[0].key()]
    assert row == {"attempts": 2, "crashes": 2, "timeouts": 0,
                   "status": "poisoned"}
    assert res == {}


def test_inline_engine_rejects_nothing_but_does_not_retry():
    """``workers=1`` runs cells in-process: no pool, no crash
    containment — the robustness knobs are pool-path only and the
    stats stay zero on a clean inline run."""
    res, stats = SweepEngine(workers=1).run(_ok_cells())
    assert len(res) == 4
    assert stats.n_retried == stats.n_poisoned == stats.n_timeouts \
        == stats.n_pool_rebuilds == 0


# ------------------------------------------------------ chaos cell family --
def test_chaos_cell_family_runs_and_is_deterministic():
    spec = CellSpec("chaos", "fifo", "gray", 0,
                    make_params(n_jobs=8, chaos_seed=5))
    a = run_cell(spec)
    assert a["n_jobs_finished"] == 8.0
    assert a["n_chaos_events"] >= 1.0
    assert a == run_cell(spec)


def test_chaos_cell_detect_toggle_changes_the_trajectory():
    on = run_cell(CellSpec("chaos", "fifo", "hostile", 0,
                           make_params(n_jobs=12, chaos_seed=5)))
    off = run_cell(CellSpec("chaos", "fifo", "hostile", 0,
                            make_params(n_jobs=12, chaos_seed=5,
                                        detect=False)))
    assert on["n_timeouts"] > 0
    assert off["n_timeouts"] == 0
    assert on["n_jobs_finished"] == off["n_jobs_finished"] == 12.0


def test_selftest_cells_need_flag_dir():
    with pytest.raises(ValueError, match="flag_dir"):
        run_cell(CellSpec("selftest", "a", "hang_once", 0))
    with pytest.raises(ValueError, match="scenario"):
        run_cell(CellSpec("selftest", "a", "nonsense", 0))
