"""Pallas kernels vs pure-jnp oracles, interpret=True, swept over shapes
and dtypes (the per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.gla_scan import gla_pallas
from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref
from repro.models.recurrence import gla_ref

ATTN_SHAPES = [
    # (B, H, Sq, Sk, D, block_q, block_k)
    (1, 1, 128, 128, 32, 64, 64),
    (2, 4, 256, 256, 64, 128, 128),
    (1, 2, 128, 384, 64, 64, 128),   # cross: Sk > Sq
    (2, 3, 64, 64, 16, 64, 64),
]


@pytest.mark.parametrize("B,H,Sq,Sk,D,bq,bk", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_allclose(B, H, Sq, Sk, D, bq, bk, dtype, causal):
    if causal and Sk != Sq:
        pytest.skip("causal requires aligned positions here")
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, Sq, D), dtype)
    k = jnp.asarray(rng.randn(B, H, Sk, D), dtype)
    v = jnp.asarray(rng.randn(B, H, Sk, D), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=1e-2)


def test_flash_attention_sliding_window():
    rng = np.random.RandomState(1)
    B, H, S, D, W = 1, 2, 256, 32, 64
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=W, interpret=True,
                          block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=1e-2)


def test_flash_attention_masked_kpos():
    """kpos == -1 slots (unwritten cache) must be ignored."""
    rng = np.random.RandomState(2)
    B, H, Sq, Sk, D = 1, 1, 64, 128, 32
    q = jnp.asarray(rng.randn(B, H, Sq, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, Sk, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, Sk, D), jnp.float32)
    kpos = jnp.where(jnp.arange(Sk) < 100, jnp.arange(Sk), -1)
    qpos = jnp.arange(64) + 36  # queries see all valid keys
    out = flash_attention(q, k, v, causal=True, qpos=qpos, kpos=kpos,
                          interpret=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=True, qpos=qpos, kpos=kpos)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=1e-2)


GLA_SHAPES = [
    # (B, T, H, K, V, chunk)
    (1, 64, 1, 8, 8, 16),
    (2, 128, 3, 16, 32, 32),
    (1, 256, 2, 64, 64, 64),
    (2, 96, 2, 16, 16, 32),
]


@pytest.mark.parametrize("B,T,H,K,V,chunk", GLA_SHAPES)
@pytest.mark.parametrize("use_u", [True, False])
def test_gla_pallas_allclose(B, T, H, K, V, chunk, use_u):
    rng = np.random.RandomState(0)
    r = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, T, H, K), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, T, H, V), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.randn(B, T, H, K), jnp.float32
                                ).clip(-3, 1))
    u = (jnp.asarray(rng.randn(H, K), jnp.float32) * 0.1) if use_u else None
    y, s = gla_pallas(r, k, v, logw, u, chunk=chunk, interpret=True)
    y_ref, s_ref = gla_ref(r, k, v, logw, u)
    np.testing.assert_allclose(y, y_ref, atol=7e-4, rtol=2e-3)
    np.testing.assert_allclose(s, s_ref, atol=7e-4, rtol=2e-3)


def test_gla_pallas_bf16_values():
    rng = np.random.RandomState(3)
    B, T, H, K, V = 1, 64, 2, 16, 16
    r = jnp.asarray(rng.randn(B, T, H, K), jnp.bfloat16)
    k = jnp.asarray(rng.randn(B, T, H, K), jnp.bfloat16) * 0.3
    v = jnp.asarray(rng.randn(B, T, H, V), jnp.bfloat16)
    logw = -jnp.exp(jnp.asarray(rng.randn(B, T, H, K), jnp.float32
                                ).clip(-3, 1))
    y, s = gla_pallas(r, k, v, logw, None, chunk=32, interpret=True)
    y_ref, s_ref = gla_ref(r, k, v, logw, None)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=0.15, rtol=5e-2)


def test_ops_wrapper_gqa_broadcast():
    """ops.flash_attention accepts model-layout GQA (G < H) inputs."""
    rng = np.random.RandomState(4)
    B, S, H, G, D = 2, 128, 8, 2, 32
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, G, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, G, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True,
                              block_q=64, block_k=64)
    from repro.models.common import attention_ref
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=1e-2)


def test_banded_attention_matches_masked_dense():
    """attention_banded == attention_ref with the same sliding window."""
    from repro.models.common import attention_banded, attention_ref
    rng = np.random.RandomState(7)
    B, S, H, G, D, W = 2, 256, 4, 2, 16, 64
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, G, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, G, D), jnp.float32)
    out = attention_banded(q, k, v, window=W)
    ref = attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=1e-2)
