"""Chaos engineering layer (PR 10 tentpole): deterministic fault
campaigns, the injection taxonomy (correlated pod outages, gray ramps,
disk-slow episodes, link faults, hung tasks), and the adaptive
timeout/quarantine response loop.

The contract under test mirrors every prior subsystem's: pay-for-play
(attached-but-calm is bit-identical to the committed goldens), per-seed
determinism (injection and decision logs are sha-stable), and graceful
degradation (every job finishes no matter what the campaign does). The
same-tick ordering matrix at the bottom is the PR's race test: a chaos
injection, a churn kill and its near-zero-notice warning land at the
same instant for all five algorithms, twice, and must replay the exact
same trajectory.
"""
import pytest

from benchmarks.bench_chaos import GATE, _calm_subsystems, _full_sig, \
    _mk, chaos_probe
from repro.chaos import (ChaosConfig, ChaosEvent, ChaosSubsystem,
                         ResponseConfig, ResponseSubsystem, build_campaign)
from repro.core.joss import make_algorithm
from repro.elastic import ChurnConfig, ChurnModel, ElasticEngine, FixedFleet
from repro.sim.cluster_sim import SimConfig, Simulator
from repro.sim.golden import case_key, golden_cases, load_golden, \
    run_case, signature_hash
from repro.sim.network import FabricConfig
from repro.sim.workloads import fabric_links, make_cluster, small_workload

ALGOS = ("joss-t", "joss-j", "fifo", "fair", "capacity")


# --------------------------------------------------------------- helpers --
def _run(algo_name, campaign, chaos_kw=None, resp=None, *,
         hosts_per_pod=(4, 4), n_jobs=12, seed=11, config_kw=None,
         elastic=None):
    """One run with an explicit (pinned) campaign. Returns the result;
    the simulator stays reachable as ``res.sim`` is not a thing, so the
    few tests that need post-run overlay state keep their own handle."""
    cluster, jobs, algo = _mk(algo_name, hosts_per_pod, n_jobs, seed)
    chaos = ChaosSubsystem(ChaosConfig(seed=0, **(chaos_kw or {})),
                           campaign=campaign)
    subs = [chaos]
    if resp is not None:
        subs.append(ResponseSubsystem(resp))
    sim = Simulator(cluster, algo, jobs,
                    config=SimConfig(**(config_kw or {})), seed=seed,
                    elastic=elastic, subsystems=tuple(subs))
    res = sim.run()
    assert len(res.job_finish) == len(jobs)
    return res, sim


def _actions(log):
    return [entry[1] for entry in log]


def _times(log, action):
    return [entry[0] for entry in log if entry[1] == action]


# ----------------------------------------------------- campaign sampling --
def test_build_campaign_deterministic_sorted_and_counted():
    cfg = ChaosConfig(seed=3, n_outages=2, n_gray=3, n_disk=1, n_link=1,
                      n_partition=1, n_hung=2)
    a = build_campaign(cfg)
    assert a == build_campaign(cfg)                    # pure in the config
    assert len(a) == cfg.n_events == 10
    assert [e.draw for e in sorted(a, key=lambda e: e.draw)] == list(range(10))
    assert all(x.time <= y.time or (x.time, x.draw) < (y.time, y.draw)
               for x, y in zip(a, a[1:]))
    assert [(e.time, e.draw) for e in a] == \
        sorted((e.time, e.draw) for e in a)
    assert all(0.0 <= e.time < cfg.horizon for e in a)
    b = build_campaign(ChaosConfig(seed=4, n_outages=2, n_gray=3, n_disk=1,
                                   n_link=1, n_partition=1, n_hung=2))
    assert a != b                                      # seed moves the draws


def test_empty_campaign_is_empty():
    assert build_campaign(ChaosConfig(seed=99)) == []


# --------------------------------------------- golden bit-identity (off) --
@pytest.mark.parametrize("algo,variant", golden_cases()[::5])
def test_calm_attached_layer_is_bit_identical_to_golden(algo, variant):
    """An attached chaos layer with an empty campaign plus an inert
    detector must not move a single event vs the committed goldens —
    the fault layer is pay-for-play like churn/fabric/telemetry."""
    res = run_case(algo, variant, subsystems=_calm_subsystems())
    assert signature_hash(res) == load_golden()[case_key(algo, variant)]
    assert res.n_chaos_events == 0 and res.n_timeouts == 0


# --------------------------------------------------- gray ramp episodes --
def test_gray_ramp_applies_steps_and_clears():
    res, sim = _run("fifo", [ChaosEvent(30.0, "gray", 5, 0)],
                    chaos_kw=dict(gray_factor=6.0, gray_s=120.0))
    log = res.chaos.log
    assert _actions(log) == ["gray_begin", "gray_step", "gray_clear"]
    t0, t1, t2 = (e[0] for e in log)
    assert (t0, t1, t2) == (30.0, 90.0, 150.0)         # full, half, recover
    assert log[0][-1] == 6.0 and log[1][-1] == 3.5     # (1 + f) / 2
    assert res.n_chaos_events == 1 and res.chaos.n_gray == 1
    assert not sim.dyn_slow                            # overlay fully cleared


def test_gray_episode_stretches_tasks_on_the_gray_host():
    """The overlay bites: tasks started on the gray host inside the
    full-factor window run exactly ``gray_factor`` times their calm
    duration; after the clear the host is back to full speed."""
    calm, _ = _run("fifo", [])
    gray, _ = _run("fifo", [ChaosEvent(30.0, "gray", 5, 0)],
                   chaos_kw=dict(gray_factor=8.0, gray_s=400.0))

    def durs(res, lo=0.0, hi=float("inf")):
        return sorted(l.finish - l.start for l in res.task_logs
                      if (l.host.pod, l.host.index) == (1, 1)
                      and lo <= l.start < hi)

    assert min(durs(gray, 30.0, 230.0)) == \
        pytest.approx(8.0 * min(durs(calm)))
    assert min(durs(gray, 430.0)) == pytest.approx(min(durs(calm)))


# ----------------------------------------------------- disk-slow episodes --
def test_disk_episode_logs_and_clears():
    res, sim = _run("fifo", [ChaosEvent(30.0, "disk", 2, 0)],
                    chaos_kw=dict(disk_factor=6.0, disk_s=150.0))
    assert _actions(res.chaos.log) == ["disk_begin", "disk_clear"]
    assert _times(res.chaos.log, "disk_clear") == [180.0]
    assert res.chaos.n_disk == 1 and not sim.dyn_disk


# ------------------------------------------------- correlated pod outages --
def test_pod_outage_kills_and_rejoins_whole_pod():
    res, sim = _run(
        "fifo", [ChaosEvent(50.0, "outage", 1, 0)],
        chaos_kw=dict(outage_gray_s=30.0, outage_gray_factor=6.0,
                      outage_down_s=90.0),
        n_jobs=8)
    cs = res.chaos
    acts = _actions(cs.log)
    assert acts[0] == "outage_begin"
    assert cs.n_outages == 1 and cs.n_killed_hosts == 4   # the whole pod
    assert acts.count("outage_kill") == acts.count("outage_rejoin") == 4
    # the prodrome precedes the kill by outage_gray_s, the rejoin lands
    # outage_down_s after it
    assert _times(cs.log, "outage_kill") == [80.0] * 4
    assert _times(cs.log, "outage_rejoin") == [170.0] * 4
    assert len(sim.all_hosts) == 8                        # fleet restored
    assert not sim.dyn_slow


def test_outage_vetoes_the_last_host():
    """The last-offerable-host veto (same discipline as the elastic
    engine): a single-host tenant survives a pod outage."""
    res, sim = _run("fifo", [ChaosEvent(20.0, "outage", 0, 0)],
                    chaos_kw=dict(outage_gray_s=10.0),
                    hosts_per_pod=(1,), n_jobs=3)
    assert res.chaos.n_killed_hosts == 0
    assert "outage_veto" in _actions(res.chaos.log)
    assert res.chaos.n_skipped == 1
    assert len(sim.all_hosts) == 1


# ----------------------------------------------------- link faults --------
def test_link_derate_and_partition_park_and_restore():
    """Fabric faults through ``set_derate``: a 25% derate and a full
    partition (zero capacity — flows park) both restore on schedule and
    the run still drains every job."""
    links = fabric_links((4, 4), wan_oversub=4.0)
    cluster = make_cluster((4, 4), links=links)
    jobs = small_workload(cluster, seed=11, n_jobs=12)
    algo = make_algorithm("fifo", cluster)
    chaos = ChaosSubsystem(
        ChaosConfig(seed=0, link_factor=0.25, link_s=60.0,
                    partition_s=45.0),
        campaign=[ChaosEvent(20.0, "link", 1, 0),
                  ChaosEvent(40.0, "partition", 2, 1)])
    res = Simulator(cluster, algo, jobs,
                    config=SimConfig(fabric=FabricConfig(
                        completion_log=False)),
                    seed=11, subsystems=(chaos,)).run()
    assert len(res.job_finish) == len(jobs)
    cs = res.chaos
    assert cs.n_link == 1 and cs.n_partition == 1
    acts = _actions(cs.log)
    assert acts.count("link_begin") == acts.count("link_end") == 1
    assert acts.count("partition_begin") == acts.count("partition_end") == 1
    assert _times(cs.log, "link_end") == [80.0]
    assert _times(cs.log, "partition_end") == [85.0]
    # the partition really zeroes the class
    pbegin = next(e for e in cs.log if e[1] == "partition_begin")
    assert pbegin[-1] == 0.0


def test_link_faults_skipped_in_per_stream_mode():
    """Per-stream (no-fabric) runs cannot express link faults: the
    campaign logs-and-skips instead of silently dropping."""
    res, _ = _run("fifo", [ChaosEvent(20.0, "link", 1, 0),
                           ChaosEvent(30.0, "partition", 0, 1)])
    assert res.n_chaos_events == 0
    assert res.chaos.n_skipped == 2
    assert _actions(res.chaos.log) == ["link_skip", "partition_skip"]


# ------------------------------------------------------------ hung tasks --
def test_hung_task_detection_beats_waiting_out_the_hang():
    """The pure gray failure: a hang frees no slot and fires no churn
    event. Detection-off waits out the full stall; the progress-based
    timeout kills and re-runs it much sooner."""
    campaign = [ChaosEvent(82.0, "hang", 1, 0)]
    kw = dict(chaos_kw=dict(hang_s=600.0))
    off, _ = _run("fifo", campaign, **kw)
    on, _ = _run("fifo", campaign, resp=ResponseConfig(grace=2.0), **kw)
    assert off.chaos.n_hung == on.chaos.n_hung == 1
    assert off.n_timeouts == 0 and on.n_timeouts >= 1
    assert on.wtt < off.wtt
    assert len(off.job_finish) == len(on.job_finish)   # both still finish


def test_surfacing_after_max_attempts_still_finishes_the_job():
    """After ``max_attempts`` timeouts the (task, index) pair is
    surfaced as a job-level failure and requeued one final unmonitored
    time — escalation never wedges the job."""
    res, _ = _run("fifo", [ChaosEvent(82.0, "hang", 1, 0)],
                  chaos_kw=dict(hang_s=5000.0),
                  resp=ResponseConfig(grace=2.0, max_attempts=1))
    rs = res.response
    assert rs.n_surfaced >= 1
    assert "surface" in _actions(rs.log)
    assert res.n_timeouts >= 1


def test_timeout_requeues_after_exponential_backoff():
    """The re-dispatch of a timed-out attempt lands exactly
    ``backoff_base * 2^(n-1)`` after the kill (capped)."""
    res = chaos_probe("joss-t", detect=True)
    rs = res.response
    by_pair = {}
    for e in rs.log:
        if e[1] == "timeout":
            by_pair.setdefault(e[2], []).append(("timeout", e[0], e[4]))
        elif e[1] in ("requeue", "requeue_moot"):
            by_pair.setdefault(e[2], []).append(("requeue", e[0], None))
    checked = 0
    for entries in by_pair.values():
        for (k1, t1, n), (k2, t2, _) in zip(entries, entries[1:]):
            if k1 == "timeout" and k2 == "requeue":
                assert t2 - t1 == pytest.approx(
                    min(120.0, 5.0 * 2.0 ** (n - 1)), abs=1e-6)
                checked += 1
    assert checked > 0


# ------------------------------------------- quarantine / probation -------
def test_gate_quarantine_excludes_host_from_offer_sets():
    """Between a host's quarantine and its re-admission no new task may
    start on it — the offer-set exclusion, asserted on the committed
    gate scenario's real trajectory."""
    res = chaos_probe("joss-t", detect=True)
    assert res.n_quarantined > 0
    windows = {}
    for e in res.response.log:
        if e[1] == "quarantine":
            windows.setdefault(e[2], []).append([e[0], float("inf")])
        elif e[1] == "readmit" and e[2] in windows:
            windows[e[2]][-1][1] = e[0]
    assert windows
    for log in res.task_logs:
        hkey = (log.host.pod, log.host.index)
        for lo, hi in windows.get(hkey, ()):
            assert not (lo < log.start < hi), \
                f"task started on quarantined host {hkey} at {log.start}"


def test_probation_readmits_at_reduced_health():
    """Direct drive of the health machinery: one quarantine, probation
    elapses mid-run, the host re-enters the offer sets at
    ``probation_health``."""
    cluster, jobs, algo = _mk("fifo", (2, 2), 8, 11)
    resp = ResponseSubsystem(ResponseConfig(quarantine_at=1.0,
                                            probation_s=50.0))
    sim = Simulator(cluster, algo, jobs, seed=11, subsystems=(resp,))
    sim.begin()
    hid = sorted(sim.all_hosts, key=lambda h: (h.pod, h.index))[0]
    resp._charge_host(hid, 0.0)
    assert hid in sim.quarantined
    assert hid not in sim.free_map_hosts and hid not in sim.free_red_hosts
    assert resp.summary.n_quarantined == 1
    res = sim.finish(sim.step())
    assert len(res.job_finish) == len(jobs)
    assert resp.summary.n_readmitted == 1
    assert hid not in sim.quarantined
    # re-admitted at probation_health; clean finishes can only refund
    assert resp.health[hid] <= 0.5 + 1e-9


def test_quarantine_vetoes_the_last_offerable_host():
    cluster, jobs, algo = _mk("fifo", (1, 1), 4, 11)
    resp = ResponseSubsystem(ResponseConfig(quarantine_at=1.0))
    sim = Simulator(cluster, algo, jobs, seed=11, subsystems=(resp,))
    sim.begin()
    h0, h1 = sorted(sim.all_hosts, key=lambda h: (h.pod, h.index))
    resp._charge_host(h0, 0.0)
    assert h0 in sim.quarantined
    resp._charge_host(h1, 0.0)
    assert h1 not in sim.quarantined       # never blacklist the last host
    assert resp.summary.n_vetoed == 1
    assert "quarantine_veto" in _actions(resp.summary.log)


@pytest.mark.parametrize("name,expect", [("joss-t", 1), ("fifo", 0)])
def test_pod_wide_quarantine_triggers_joss_degradation(name, expect):
    """Quarantining a whole pod fires the JoSS ``pod_degraded`` hook
    (queued work re-buckets to healthy pods); algorithms without the
    hook are untouched — and both still finish every job."""
    cluster, jobs, algo = _mk(name, (2, 2), 8, 11)
    resp = ResponseSubsystem(ResponseConfig(quarantine_at=1.0,
                                            probation_s=1e9))
    sim = Simulator(cluster, algo, jobs, seed=11, subsystems=(resp,))
    sim.begin()
    for hid in sorted((h for h in sim.all_hosts if h.pod == 0),
                      key=lambda h: (h.pod, h.index)):
        resp._charge_host(hid, 0.0)
    assert resp.summary.n_quarantined == 2
    assert resp.summary.n_pods_degraded == expect
    res = sim.finish(sim.step())
    assert len(res.job_finish) == len(jobs)   # pod 1 absorbs everything


# --------------------------------------------- the committed gate claims --
@pytest.mark.parametrize("name", ALGOS)
def test_detection_cuts_wtt_and_reexec_on_the_gate(name):
    """The acceptance criterion, standalone per algorithm: on the
    committed hostile-campaign gate, the timeout+quarantine loop beats
    detection-off on WTT and re-executions with every job finishing."""
    off = chaos_probe(name, detect=False)
    on = chaos_probe(name, detect=True)
    assert on.wtt < off.wtt
    assert on.n_reexec < off.n_reexec
    assert on.n_timeouts > 0 and on.n_quarantined > 0


def test_gate_runs_are_deterministic_per_seed():
    a = chaos_probe("joss-j", detect=True)
    b = chaos_probe("joss-j", detect=True)
    assert a.chaos.signature() == b.chaos.signature()
    assert a.response.signature() == b.response.signature()
    assert _full_sig(a) == _full_sig(b)


# --------------------- same-tick chaos vs churn vs notice (the satellite) --
def _collision_point(seed):
    """Deterministic same-instant collision: read the churn model's
    pre-sampled preempt kill times (the trace is workload-independent)
    and pin chaos injections at exactly those floats. ``preempt_notice``
    of 1e-9 places the notice essentially *at* the kill, so notice
    delivery, the kill itself and the chaos op all land in one tick."""
    churn_kw = dict(spot_fraction=0.5, spot_preempt_rate=6.0,
                    preempt_notice=1e-9, horizon=1500.0)
    cluster = make_cluster((4, 4))
    cfg = ChurnConfig(seed=seed + 1, **churn_kw)
    _, events = ChurnModel(cfg).initial_trace(cluster)
    kills = sorted(e.time for e in events if e.kind == "preempt")
    assert len(kills) >= 2, "collision scenario lost its churn kills"
    campaign = [ChaosEvent(kills[0], "gray", 3, 0),
                ChaosEvent(kills[0], "hang", 1, 1),
                ChaosEvent(kills[1], "outage", 0, 2)]
    return churn_kw, campaign


@pytest.mark.parametrize("name", ALGOS)
def test_same_tick_chaos_churn_notice_is_deterministic(name):
    """The race matrix: a gray ramp and a hang at the exact instant of
    one spot kill (plus its same-instant notice), a pod outage at the
    instant of another — for every algorithm, twice. The tie-break
    (kernel insertion order: churn before chaos before response) must
    replay bit-identically, and every job must still finish."""
    seed = 7
    churn_kw, campaign = _collision_point(seed)

    def once():
        cluster, jobs, algo = _mk(name, (4, 4), 16, seed)
        eng = ElasticEngine(cluster,
                            churn=ChurnConfig(seed=seed + 1, **churn_kw),
                            autoscaler=FixedFleet())
        chaos = ChaosSubsystem(
            ChaosConfig(seed=0, gray_factor=6.0, gray_s=120.0,
                        hang_s=300.0, outage_gray_s=60.0,
                        outage_down_s=120.0),
            campaign=campaign)
        resp = ResponseSubsystem(ResponseConfig(grace=2.0))
        res = Simulator(cluster, algo, jobs, seed=seed, elastic=eng,
                        subsystems=(chaos, resp)).run()
        assert len(res.job_finish) == len(jobs)
        return res

    a, b = once(), once()
    assert a.chaos.signature() == b.chaos.signature()
    assert a.response.signature() == b.response.signature()
    assert _full_sig(a) == _full_sig(b)
    # the collision genuinely happened: chaos fired and churn killed
    assert a.n_chaos_events >= 1
    assert a.n_host_losses >= 1
    chaos_times = {e[0] for e in a.chaos.log
                   if e[1] in ("gray_begin", "hang", "outage_begin")}
    kill_times = {round(t, 6) for t in
                  (e.time for e in _churn_kills(seed, churn_kw))}
    assert chaos_times & kill_times, \
        "no chaos op actually shared an instant with a churn kill"


def _churn_kills(seed, churn_kw):
    cluster = make_cluster((4, 4))
    _, events = ChurnModel(ChurnConfig(seed=seed + 1,
                                       **churn_kw)).initial_trace(cluster)
    return [e for e in events if e.kind == "preempt"]
