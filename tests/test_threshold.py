"""Paper §5: the td = k/(k-1) threshold is the worst-case-INT optimizer
(Eqs. 5-8), verified as a property over job shapes and cluster sizes."""
import math

import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.classifier import (best_threshold, worst_case_traffic_mh,
                                   worst_case_traffic_rh)


def test_threshold_formula():
    assert best_threshold(2) == 2.0
    assert best_threshold(3) == 1.5
    assert abs(best_threshold(10) - 10 / 9) < 1e-12


def test_threshold_requires_multiple_pods():
    with pytest.raises(ValueError):
        best_threshold(1)


@given(k=st.integers(2, 64),
       s_map=st.floats(1.0, 1e7),
       fp=st.floats(0.0, 50.0))
@settings(max_examples=300, deadline=None)
def test_td_picks_lower_worst_case_traffic(k, s_map, fp):
    """Classifying by FP > td must choose the side with the smaller
    worst-case inter-pod traffic (the §5 argument, as a property)."""
    td = best_threshold(k)
    tr_rh = worst_case_traffic_rh(s_map)            # policy A worst case
    tr_mh = worst_case_traffic_mh(s_map, fp, k)     # policy B worst case
    if fp > td:   # classified RH -> policy A must not be worse
        assert tr_rh <= tr_mh * (1 + 1e-9)
    else:         # classified MH -> policy B must not be worse
        assert tr_mh <= tr_rh * (1 + 1e-9)


@given(k=st.integers(2, 64), s_map=st.floats(1.0, 1e7))
@settings(max_examples=100, deadline=None)
def test_td_is_the_crossover_point(k, s_map):
    """At FP = td the two worst cases are exactly equal — td is tight:
    any other threshold misclassifies some FP region."""
    td = best_threshold(k)
    tr_rh = worst_case_traffic_rh(s_map)
    tr_mh = worst_case_traffic_mh(s_map, td, k)
    assert tr_rh == pytest.approx(tr_mh, rel=1e-9)


@given(k=st.integers(2, 32), fp=st.floats(0.0, 10.0),
       eps=st.floats(0.01, 0.5))
@settings(max_examples=200, deadline=None)
def test_any_other_threshold_is_dominated(k, fp, eps):
    """A threshold td' != td makes a strictly worse choice for some FP in
    the gap between td' and td (here: the given fp if it lands there)."""
    td = best_threshold(k)
    s_map = 1000.0
    for td_other in (td * (1 + eps), td * (1 - eps)):
        lo, hi = sorted((td, td_other))
        if not (lo < fp <= hi):
            continue
        choice_other = "RH" if fp > td_other else "MH"
        tr = {"RH": worst_case_traffic_rh(s_map),
              "MH": worst_case_traffic_mh(s_map, fp, k)}
        choice_opt = "RH" if fp > td else "MH"
        assert tr[choice_opt] <= tr[choice_other] * (1 + 1e-9)
