"""PR 4 tentpole: the contention-aware network fabric.

Covers the max-min fair-share allocator (rates, per-flow caps, progress
under churned flow sets), per-stream parity on an uncontended fabric,
contention actually slowing transfers, per-seed determinism of flow
completion order, repair traffic as fabric flows, and the speculative-
backup-reads-the-store satellite.
"""
import pytest

from repro.core.joss import make_algorithm
from repro.core.topology import HostId, LinkCapacities
from repro.sim.cluster_sim import SimConfig, Simulator
from repro.sim.engine import EventKernel
from repro.sim.network import FabricConfig, NetworkFabric
from repro.sim.workloads import (fabric_links, fabric_scenarios,
                                 make_cluster, profiling_prelude,
                                 small_workload)


# ------------------------------------------------------------- allocator --
def _bare_fabric(links, pods=2):
    class _Sim:
        pass
    cluster = make_cluster((2,) * pods, links=links)
    fab = NetworkFabric(cluster)
    k = EventKernel()
    fab.attach(_Sim(), k)
    return fab, k


def test_max_min_equal_share_on_bottleneck():
    fab, k = _bare_fabric(LinkCapacities(pod_up=1e6, pod_down=1e6, wan=120.0))
    done = []
    for i in range(3):
        fab.start_flow(0.0, 100.0, 0, 1, cap=1e6, kind="t",
                       done=lambda now, i=i: done.append((i, now)))
    rates = sorted(f.rate for f in fab._flows.values())
    assert rates == pytest.approx([40.0, 40.0, 40.0])   # 120 / 3
    k.run()
    assert [i for i, _ in done] == [0, 1, 2]
    assert done[0][1] == pytest.approx(100.0 / 40.0)


def test_max_min_respects_per_flow_caps():
    fab, _k = _bare_fabric(LinkCapacities(pod_up=1e6, pod_down=1e6,
                                          wan=120.0))
    fab.start_flow(0.0, 100.0, 0, 1, cap=10.0, kind="t", done=lambda n: None)
    fab.start_flow(0.0, 100.0, 0, 1, cap=1e6, kind="t", done=lambda n: None)
    fab.start_flow(0.0, 100.0, 0, 1, cap=1e6, kind="t", done=lambda n: None)
    by_cap = sorted((f.cap, f.rate) for f in fab._flows.values())
    assert by_cap[0][1] == pytest.approx(10.0)        # capped flow
    assert by_cap[1][1] == pytest.approx(55.0)        # (120-10)/2 each
    assert by_cap[2][1] == pytest.approx(55.0)


def test_max_min_multilink_paths():
    """An intra-pod flow (up0+down0) and an inter-pod flow (up0+wan+down1)
    share up0; the wan constrains only the inter-pod flow."""
    fab, _k = _bare_fabric(LinkCapacities(pod_up=100.0, pod_down=1e6,
                                          wan=30.0))
    fab.start_flow(0.0, 50.0, 0, 0, cap=1e6, kind="intra",
                   done=lambda n: None)
    fab.start_flow(0.0, 50.0, 0, 1, cap=1e6, kind="inter",
                   done=lambda n: None)
    rates = {f.kind: f.rate for f in fab._flows.values()}
    assert rates["inter"] == pytest.approx(30.0)      # wan-bound
    assert rates["intra"] == pytest.approx(70.0)      # rest of up0


def test_flow_rates_rebalance_on_completion():
    fab, k = _bare_fabric(LinkCapacities(pod_up=1e6, pod_down=1e6,
                                         wan=100.0))
    times = {}
    fab.start_flow(0.0, 50.0, 0, 1, cap=1e6, kind="short",
                   done=lambda now: times.setdefault("short", now))
    fab.start_flow(0.0, 150.0, 0, 1, cap=1e6, kind="long",
                   done=lambda now: times.setdefault("long", now))
    k.run()
    # both run at 50 until the short one finishes at t=1; the long one
    # then takes the full 100: 150 = 50*1 + 100*(t-1) -> t = 2.0
    assert times["short"] == pytest.approx(1.0)
    assert times["long"] == pytest.approx(2.0)
    # stall vs each flow's (negligible) uncontended time at cap=1e6
    assert fab.summary.stall_s == pytest.approx(3.0, abs=1e-3)


def test_cancel_removes_flow_and_rebalances():
    fab, k = _bare_fabric(LinkCapacities(pod_up=1e6, pod_down=1e6,
                                         wan=100.0))
    times = {}
    fid = fab.start_flow(0.0, 1000.0, 0, 1, cap=1e6, kind="dying",
                         done=lambda now: times.setdefault("dying", now))
    fab.start_flow(0.0, 100.0, 0, 1, cap=1e6, kind="survivor",
                   done=lambda now: times.setdefault("survivor", now))
    fab.cancel(fid, 1.0)
    k.run()
    assert "dying" not in times
    assert fab.summary.n_cancelled == 1
    # survivor: 50 MB moved by t=1, the remaining 50 at the full 100 MB/s
    assert times["survivor"] == pytest.approx(1.5)


def test_external_ingress_skips_pod_uplinks():
    fab, _k = _bare_fabric(LinkCapacities(pod_up=1.0, pod_down=1e6,
                                          wan=200.0))
    fab.start_flow(0.0, 10.0, None, 1, cap=1e6, kind="ext",
                   done=lambda n: None)
    (f,) = fab._flows.values()
    assert f.rate == pytest.approx(200.0)   # tiny uplinks don't matter


def test_zero_byte_flow_completes_via_kernel():
    fab, k = _bare_fabric(LinkCapacities())
    done = []
    assert fab.start_flow(3.0, 0.0, 0, 1, cap=10.0, kind="t",
                          done=lambda now: done.append(now)) == -1
    k.run()
    assert done == [3.0]


# ----------------------------------------------------------- end-to-end --
def _run(name, links=None, *, n_jobs=10, seed=11, elastic=None, cfg_kw=None):
    cluster = make_cluster((4, 4), links=links)
    jobs = small_workload(cluster, seed=seed, n_jobs=n_jobs)
    algo = make_algorithm(name, cluster)
    if hasattr(algo, "registry"):
        for j in profiling_prelude(cluster):
            algo.registry.record(j, j.true_fp)
    cfg = SimConfig(fabric=FabricConfig() if links is not None else None,
                    **(cfg_kw or {}))
    res = Simulator(cluster, algo, jobs, config=cfg, seed=seed,
                    elastic=elastic(cluster) if elastic else None).run()
    assert len(res.job_finish) == n_jobs
    return res


def test_uncontended_fabric_matches_per_stream_wtt():
    """With plentiful links and per-flow caps at the per-stream rates,
    the flow model reproduces per-stream timing (spread arrivals)."""
    wide = LinkCapacities(pod_up=1e6, pod_down=1e6, wan=1e6)
    for name in ("joss-t", "fifo"):
        a = _run(name)
        b = _run(name, wide)
        assert b.wtt == pytest.approx(a.wtt, rel=1e-6), name
        assert b.fabric_stall_s == pytest.approx(0.0, abs=1e-6)
        # placements may legitimately differ where same-instant events
        # tie (push order differs between the modes), so INT is only
        # required to be close, not bit-equal
        assert b.int_bytes == pytest.approx(a.int_bytes, rel=0.1)


def test_contention_slows_transfers_and_stalls_accrue():
    tight = fabric_links((4, 4), wan_oversub=16.0)
    wide = LinkCapacities(pod_up=1e6, pod_down=1e6, wan=1e6)
    a = _run("fifo", wide)
    b = _run("fifo", tight)
    assert b.fabric_stall_s > 10.0
    assert b.wtt > a.wtt
    assert b.wan_util > a.wan_util


def test_flow_completion_order_deterministic_per_seed():
    from repro.elastic import ChurnConfig, DurabilityConfig, ElasticEngine, \
        FixedFleet

    def eng(cluster):
        return ElasticEngine(
            cluster,
            churn=ChurnConfig(seed=12, fail_rate=4.0, rejoin_delay=60.0),
            autoscaler=FixedFleet(),
            durability=DurabilityConfig(rereplicate=True, rerep_delay=5.0,
                                        checkpoint=True))
    tight = fabric_links((4, 4), wan_oversub=8.0)
    a = _run("joss-t", tight, elastic=eng)
    b = _run("joss-t", tight, elastic=eng)
    assert a.fabric.completion_log == b.fabric.completion_log
    assert a.fabric.completion_log, "run produced no flows"
    assert a.wtt == b.wtt and a.n_rerep == b.n_rerep


def test_rerep_repairs_travel_as_fabric_flows():
    from repro.elastic import ChurnConfig, DurabilityConfig, ElasticEngine, \
        FixedFleet

    def eng(cluster):
        return ElasticEngine(
            cluster,
            churn=ChurnConfig(seed=12, fail_rate=4.0, rejoin_delay=60.0),
            autoscaler=FixedFleet(),
            durability=DurabilityConfig(rereplicate=True, rerep_delay=5.0,
                                        rerep_bandwidth=150.0))
    res = _run("joss-t", fabric_links((4, 4)), elastic=eng)
    assert res.n_rerep > 0
    kinds = res.fabric.by_kind
    assert "rerep" in kinds and kinds["rerep"][1] == pytest.approx(
        res.rerep_mb), "repair MB must drain through the fabric"


def test_ckpt_traffic_travels_as_fabric_flows():
    from repro.elastic import ChurnConfig, DurabilityConfig, ElasticEngine, \
        FixedFleet

    def eng(cluster):
        return ElasticEngine(
            cluster,
            churn=ChurnConfig(seed=12, fail_rate=4.0, rejoin_delay=60.0),
            autoscaler=FixedFleet(),
            durability=DurabilityConfig(checkpoint=True))
    res = _run("joss-t", fabric_links((4, 4)), elastic=eng)
    assert res.ckpt_mb_written > 0
    # equality holds without speculation; a losing speculative twin's
    # write drains through the fabric but is not billed (PR 3 semantics)
    assert res.fabric.by_kind["ckpt_write"][1] == pytest.approx(
        res.ckpt_mb_written)


def test_completion_log_can_be_disabled():
    from repro.sim.network import FabricConfig as FC
    cluster = make_cluster((4, 4))
    jobs = small_workload(cluster, seed=11, n_jobs=4)
    algo = make_algorithm("fifo", cluster)
    cfg = SimConfig(fabric=FC(links=fabric_links((4, 4)),
                              completion_log=False))
    res = Simulator(cluster, algo, jobs, config=cfg, seed=11).run()
    assert res.fabric.n_flows > 0
    assert res.fabric.completion_log == []


# --------------------------------------- speculative backups x durability --
def _spec_run(ckpt: bool):
    """A straggler scenario under checkpointing: the backup of a
    checkpointed job's map should fetch the pod object store."""
    from repro.elastic import DurabilityConfig, ElasticEngine, FixedFleet
    cluster = make_cluster((4, 4))
    jobs = small_workload(cluster, seed=11, n_jobs=12)
    algo = make_algorithm("fifo", cluster)
    eng = ElasticEngine(
        cluster, autoscaler=FixedFleet(),
        durability=(DurabilityConfig(checkpoint=True) if ckpt else None))
    cfg = SimConfig(speculative=True, slow_hosts={HostId(0, 0): 4.0})
    res = Simulator(cluster, algo, jobs, config=cfg, seed=11,
                    elastic=eng).run()
    assert len(res.job_finish) == 12
    return res


def test_speculative_backup_reads_pod_store_when_checkpointed():
    base = _spec_run(ckpt=False)
    ck = _spec_run(ckpt=True)
    base_spec = [l for l in base.task_logs if l.speculative]
    ck_spec = [l for l in ck.task_logs if l.speculative]
    assert base_spec and ck_spec, "no speculative backups launched"
    # without the store, backups placed in the other pod re-read the
    # shard across the WAN; with it every backup is a pod-store read
    assert any(l.bytes_offpod > 0 for l in base_spec)
    assert all(l.bytes_pod > 0 and l.bytes_offpod == 0 and
               l.bytes_local == 0 for l in ck_spec)
    assert sum(l.bytes_offpod for l in ck_spec) < \
        sum(l.bytes_offpod for l in base_spec)


def test_fabric_scenarios_shapes():
    scen = fabric_scenarios((8, 8))
    assert list(scen) == ["uncontended", "oversub8", "oversub24"]
    assert scen["oversub8"].wan == pytest.approx(
        scen["uncontended"].wan / 8.0)
    assert scen["oversub24"].wan < scen["oversub8"].wan
    with pytest.raises(ValueError):
        LinkCapacities(pod_up=0.0)
